//! Deterministic discrete-event queue.
//!
//! A binary heap keyed by `(time, sequence)`: events pop in time order,
//! and events scheduled for the same instant pop in the order they were
//! scheduled. The payload type `E` needs no ordering of its own, so any
//! event enum can ride the queue.

use super::clock::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Scheduled<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest
        // (time, seq) on top.
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// The event queue. `schedule` is O(log n), `pop` is O(log n).
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at virtual time `at`.
    pub fn schedule(&mut self, at: SimTime, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled {
            time: at,
            seq,
            event,
        });
    }

    /// Pop the earliest event (ties broken by schedule order).
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|s| (s.time, s.event))
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(SimTime(30), "c");
        q.schedule(SimTime(10), "a");
        q.schedule(SimTime(20), "b");
        assert_eq!(q.peek_time(), Some(SimTime(10)));
        assert_eq!(q.pop(), Some((SimTime(10), "a")));
        assert_eq!(q.pop(), Some((SimTime(20), "b")));
        assert_eq!(q.pop(), Some((SimTime(30), "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn simultaneous_events_pop_in_schedule_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule(SimTime(5), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((SimTime(5), i)));
        }
    }

    #[test]
    fn interleaved_schedule_and_pop() {
        let mut q = EventQueue::new();
        q.schedule(SimTime(10), 1);
        q.schedule(SimTime(5), 0);
        assert_eq!(q.pop(), Some((SimTime(5), 0)));
        // An event scheduled later but timed earlier than the remaining one
        // still pops first.
        q.schedule(SimTime(7), 2);
        assert_eq!(q.pop(), Some((SimTime(7), 2)));
        assert_eq!(q.pop(), Some((SimTime(10), 1)));
        assert_eq!(q.len(), 0);
    }
}
