//! Experiment configuration.
//!
//! Two layers:
//! * typed configs consumed by the engines ([`GadmmConfig`], [`PsConfig`],
//!   [`QuantConfig`], [`NetConfig`]) with paper-faithful defaults;
//! * a minimal `key = value` config-file format ([`KvMap`], a TOML subset:
//!   comments with `#`, bare sections ignored) so runs are scriptable
//!   without `serde`. CLI flags override file values (see `cli`).

use crate::coordinator::residuals::RhoPolicy;
use crate::model::BlockLayout;
use crate::net::channel::ChannelParams;
use crate::net::topology::TopologyKind;
use crate::quant::compress::{BlockCompressor, Censored, CompressorKind, FullPrecision, TopK};
use crate::quant::{BitPolicy, StochasticQuantizer};
use crate::runtime::session::{DriverKind, ProblemKind};
use crate::sim::link::{ComputeModel, LatencyModel, LossModel};
use std::collections::BTreeMap;

/// Stochastic-quantizer configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantConfig {
    /// Fixed bit-width `b` (paper: 2 for linreg, 8 for the DNN task).
    pub bits: u8,
    /// Use the adaptive eq. (11) rule instead of a fixed width.
    pub adaptive: bool,
    /// Cap for the adaptive rule.
    pub max_bits: u8,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            bits: 2,
            adaptive: false,
            max_bits: 16,
        }
    }
}

impl QuantConfig {
    pub fn policy(&self) -> BitPolicy {
        if self.adaptive {
            BitPolicy::Adaptive {
                min_bits: self.bits,
                max_bits: self.max_bits,
            }
        } else {
            BitPolicy::Fixed(self.bits)
        }
    }
}

/// Per-link compression scheme — the config-layer description a runtime
/// turns into one `quant::compress::CompressorKind` per worker
/// ([`CompressorConfig::build`], or [`CompressorConfig::build_for`] when
/// the problem's [`BlockLayout`] matters). `Stochastic(QuantConfig::
/// default())` is the paper's Q-GADMM; `FullPrecision` is the GADMM
/// baseline (the old `quant: None`); `Blocks` composes one flat scheme per
/// parameter block (`--compressor "layers:w1=stochastic@4,w2=full"`).
#[derive(Clone, Debug, PartialEq)]
pub enum CompressorConfig {
    /// Full-precision 32·d-bit broadcasts (GADMM / SGADMM).
    FullPrecision,
    /// Stochastic quantization, eqs. (6)–(13) (Q-GADMM / Q-SGADMM).
    Stochastic(QuantConfig),
    /// CQ-GGADMM-style censored stochastic quantization: skip rounds whose
    /// pending change is at or below `τ₀·decay^k`.
    Censored {
        quant: QuantConfig,
        tau0: f32,
        decay: f32,
    },
    /// Top-k sparsification with error feedback: keep `ceil(frac·d)`
    /// coordinates per round, values in full precision.
    TopK { frac: f32 },
    /// Layer-wise composition: one *flat* scheme per named parameter block
    /// of the problem's [`BlockLayout`], in spec order. Must name every
    /// block exactly once ([`CompressorConfig::validate_blocks`]); built
    /// against a concrete layout via [`CompressorConfig::build_for`].
    Blocks(Vec<(String, CompressorConfig)>),
}

/// Default censoring threshold `τ₀` (`censored` with no arguments).
pub const CENSOR_TAU0: f32 = 0.05;
/// Default censoring decay per iteration (`censored` with ≤ 1 argument).
pub const CENSOR_DECAY: f32 = 0.9985;
/// Default top-k fraction (`topk` with no argument).
pub const TOPK_FRAC: f32 = 0.02;

/// The scheme list every parse error cites.
pub const COMPRESSOR_SCHEMES: &str = "stochastic, full, censored[:tau0[:decay]], topk[:frac], \
     uniform[:scheme], layers:<block>=<scheme>[@bits][:params],...";

impl Default for CompressorConfig {
    fn default() -> Self {
        CompressorConfig::Stochastic(QuantConfig::default())
    }
}

impl From<Option<QuantConfig>> for CompressorConfig {
    /// The pre-redesign `quant: Option<QuantConfig>` encoding: `Some` ⇒
    /// stochastic quantization, `None` ⇒ full precision.
    fn from(quant: Option<QuantConfig>) -> Self {
        match quant {
            Some(q) => CompressorConfig::Stochastic(q),
            None => CompressorConfig::FullPrecision,
        }
    }
}

impl CompressorConfig {
    /// Parse a `--compressor` / `compressor=` value. Quantizing schemes
    /// inherit `base` for their bit policy (so `--bits` composes with
    /// `--compressor` regardless of flag order). Unknown schemes and
    /// malformed parameters are typed errors naming the valid set — never
    /// a silent default.
    ///
    /// Two spec families:
    /// * flat: `stochastic`, `full`, `censored[:tau0[:decay]]`,
    ///   `topk[:frac]`, plus the `uniform[:scheme]` alias that applies one
    ///   flat scheme to the whole parameter vector (today's behavior,
    ///   bit-for-bit — `uniform` alone is the default stochastic scheme);
    /// * layer-wise: `layers:<block>=<scheme>[@bits][:params],...` — one
    ///   flat scheme per named parameter block, e.g.
    ///   `layers:w1=stochastic@4,w2=topk:0.1,w3=full`. `@bits` overrides
    ///   the inherited quantizer width for that block only.
    pub fn parse(text: &str, base: QuantConfig) -> Result<CompressorConfig, String> {
        let trimmed = text.trim();
        if let Some(items) = trimmed.strip_prefix("layers:") {
            return Self::parse_layers(items, base);
        }
        if trimmed == "layers" {
            return Err(
                "layers needs a per-block spec: layers:<block>=<scheme>[@bits][:params],..."
                    .to_string(),
            );
        }
        if trimmed == "uniform" {
            return Ok(CompressorConfig::Stochastic(base));
        }
        if let Some(spec) = trimmed.strip_prefix("uniform:") {
            return Self::parse_flat(spec, base);
        }
        Self::parse_flat(trimmed, base)
    }

    /// Parse one `layers:` item list (the part after the prefix).
    fn parse_layers(items: &str, base: QuantConfig) -> Result<CompressorConfig, String> {
        let mut blocks: Vec<(String, CompressorConfig)> = Vec::new();
        for item in items.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (name, spec) = item.split_once('=').ok_or_else(|| {
                format!("bad layer spec {item:?} (want <block>=<scheme>[@bits][:params])")
            })?;
            let name = name.trim();
            if name.is_empty() {
                return Err(format!("bad layer spec {item:?}: empty block name"));
            }
            if blocks.iter().any(|(n, _)| n == name) {
                return Err(format!("block {name:?} listed twice in layer spec"));
            }
            // Peel an optional `@bits` width off the scheme token before
            // the flat parser sees the spec.
            let spec = spec.trim();
            let (scheme_tok, params) = match spec.split_once(':') {
                Some((s, p)) => (s.trim(), Some(p)),
                None => (spec, None),
            };
            let (scheme, explicit_bits) = match scheme_tok.split_once('@') {
                Some((s, b)) => {
                    let bits = b
                        .trim()
                        .parse::<u8>()
                        .ok()
                        .filter(|b| *b >= 1)
                        .ok_or_else(|| {
                            format!("bad bit width {b:?} in layer spec {item:?} (want u8 >= 1)")
                        })?;
                    (s.trim(), Some(bits))
                }
                None => (scheme_tok, None),
            };
            if scheme == "layers" || scheme == "uniform" {
                return Err(format!("layer spec {item:?}: layer specs cannot nest"));
            }
            let item_base = match explicit_bits {
                Some(bits) => QuantConfig { bits, ..base },
                None => base,
            };
            let flat_spec = match params {
                Some(p) => format!("{scheme}:{p}"),
                None => scheme.to_string(),
            };
            let sub = Self::parse_flat(&flat_spec, item_base)
                .map_err(|e| format!("layer {name:?}: {e}"))?;
            if explicit_bits.is_some() && sub.quant().is_none() {
                return Err(format!(
                    "layer {name:?}: a bit width applies to the quantizing schemes \
                     (stochastic, censored), not {}",
                    sub.name()
                ));
            }
            blocks.push((name.to_string(), sub));
        }
        if blocks.is_empty() {
            return Err(
                "layers spec lists no blocks; want layers:<block>=<scheme>[@bits][:params],..."
                    .to_string(),
            );
        }
        Ok(CompressorConfig::Blocks(blocks))
    }

    /// Parse one flat (single-scheme) spec.
    fn parse_flat(text: &str, base: QuantConfig) -> Result<CompressorConfig, String> {
        let mut parts = text.split(':');
        let scheme = parts.next().unwrap_or("").trim();
        let args: Vec<&str> = parts.map(|s| s.trim()).collect();
        let no_args = |args: &[&str]| -> Result<(), String> {
            if args.is_empty() {
                Ok(())
            } else {
                Err(format!("scheme {scheme:?} takes no parameters"))
            }
        };
        match scheme {
            "stochastic" | "quantized" => {
                no_args(&args)?;
                Ok(CompressorConfig::Stochastic(base))
            }
            "full" | "full-precision" | "none" => {
                no_args(&args)?;
                Ok(CompressorConfig::FullPrecision)
            }
            "censored" => {
                if args.len() > 2 {
                    return Err(format!(
                        "censored takes at most tau0 and decay, got {} parameters",
                        args.len()
                    ));
                }
                let tau0 = match args.first() {
                    Some(a) => a
                        .parse::<f32>()
                        .ok()
                        .filter(|t| t.is_finite() && *t >= 0.0)
                        .ok_or_else(|| format!("bad censoring tau0 {a:?} (want f32 >= 0)"))?,
                    None => CENSOR_TAU0,
                };
                let decay = match args.get(1) {
                    Some(a) => a
                        .parse::<f32>()
                        .ok()
                        .filter(|d| *d > 0.0 && *d <= 1.0)
                        .ok_or_else(|| {
                            format!("bad censoring decay {a:?} (want f32 in (0, 1])")
                        })?,
                    None => CENSOR_DECAY,
                };
                Ok(CompressorConfig::Censored {
                    quant: base,
                    tau0,
                    decay,
                })
            }
            "topk" | "top-k" => {
                if args.len() > 1 {
                    return Err(format!(
                        "topk takes at most one fraction, got {} parameters",
                        args.len()
                    ));
                }
                let frac = match args.first() {
                    Some(a) => a
                        .parse::<f32>()
                        .ok()
                        .filter(|f| *f > 0.0 && *f <= 1.0)
                        .ok_or_else(|| {
                            format!("bad top-k fraction {a:?} (want f32 in (0, 1])")
                        })?,
                    None => TOPK_FRAC,
                };
                Ok(CompressorConfig::TopK { frac })
            }
            other => Err(format!(
                "unknown compression scheme {other:?}; valid schemes: {COMPRESSOR_SCHEMES}"
            )),
        }
    }

    /// Scheme name as spelled on the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            CompressorConfig::FullPrecision => "full",
            CompressorConfig::Stochastic(_) => "stochastic",
            CompressorConfig::Censored { .. } => "censored",
            CompressorConfig::TopK { .. } => "topk",
            CompressorConfig::Blocks(_) => "layers",
        }
    }

    /// Bit policy of the quantizing schemes (`None` for full / top-k, and
    /// for the layer-wise composition, whose widths live per block).
    pub fn quant(&self) -> Option<QuantConfig> {
        match self {
            CompressorConfig::Stochastic(q) => Some(*q),
            CompressorConfig::Censored { quant, .. } => Some(*quant),
            CompressorConfig::FullPrecision
            | CompressorConfig::TopK { .. }
            | CompressorConfig::Blocks(_) => None,
        }
    }

    /// Apply the historical `bits=` key: `0` ⇒ full precision; `b > 0`
    /// sets the quantizer width (promoting full precision to stochastic).
    /// Errors on top-k, whose payload carries no quantizer width.
    pub fn with_bits(self, bits: u8) -> Result<CompressorConfig, String> {
        if let CompressorConfig::Blocks(_) = &self {
            return Err(format!(
                "bits={bits} does not apply to a layer-wise compressor; set per-block \
                 widths in the layers spec (e.g. layers:w1=stochastic@4)"
            ));
        }
        if bits == 0 {
            return Ok(CompressorConfig::FullPrecision);
        }
        match self {
            CompressorConfig::FullPrecision => Ok(CompressorConfig::Stochastic(QuantConfig {
                bits,
                ..QuantConfig::default()
            })),
            CompressorConfig::Stochastic(mut q) => {
                q.bits = bits;
                Ok(CompressorConfig::Stochastic(q))
            }
            CompressorConfig::Censored {
                mut quant,
                tau0,
                decay,
            } => {
                quant.bits = bits;
                Ok(CompressorConfig::Censored { quant, tau0, decay })
            }
            CompressorConfig::TopK { .. } => Err(format!(
                "bits={bits} applies to the quantizing compressors (stochastic, censored), \
                 not topk"
            )),
            CompressorConfig::Blocks(_) => unreachable!("rejected above"),
        }
    }

    /// Apply the `adaptive_bits=` key to the quantizing schemes (promoting
    /// full precision to stochastic, matching the pre-redesign behavior).
    pub fn with_adaptive(self, adaptive: bool) -> Result<CompressorConfig, String> {
        match self {
            CompressorConfig::FullPrecision => Ok(CompressorConfig::Stochastic(QuantConfig {
                adaptive,
                ..QuantConfig::default()
            })),
            CompressorConfig::Stochastic(mut q) => {
                q.adaptive = adaptive;
                Ok(CompressorConfig::Stochastic(q))
            }
            CompressorConfig::Censored {
                mut quant,
                tau0,
                decay,
            } => {
                quant.adaptive = adaptive;
                Ok(CompressorConfig::Censored { quant, tau0, decay })
            }
            CompressorConfig::TopK { .. } => Err(
                "adaptive_bits applies to the quantizing compressors (stochastic, censored), \
                 not topk"
                    .to_string(),
            ),
            CompressorConfig::Blocks(_) => Err(
                "adaptive_bits does not apply to a layer-wise compressor; pick per-block \
                 widths in the layers spec"
                    .to_string(),
            ),
        }
    }

    /// Can `--use-xla` drive this scheme? The PJRT artifacts are validated
    /// against the stochastic-quantizer and full-precision pipelines only
    /// (`artifact_parity`); censored/top-k/layer-wise runs must use the
    /// native backend.
    pub fn xla_compatible(&self) -> bool {
        matches!(
            self,
            CompressorConfig::Stochastic(_) | CompressorConfig::FullPrecision
        )
    }

    /// Instantiate one sender-side compressor of this scheme for a
    /// `dims`-dimensional model. Panics on the layer-wise composition,
    /// which needs a concrete [`BlockLayout`] — use
    /// [`CompressorConfig::build_for`] there.
    pub fn build(&self, dims: usize) -> CompressorKind {
        match self {
            CompressorConfig::FullPrecision => {
                CompressorKind::FullPrecision(FullPrecision::new(dims))
            }
            CompressorConfig::Stochastic(q) => {
                CompressorKind::Stochastic(StochasticQuantizer::new(dims, q.policy()))
            }
            CompressorConfig::Censored { quant, tau0, decay } => CompressorKind::Censored(
                Censored::new(StochasticQuantizer::new(dims, quant.policy()), *tau0, *decay),
            ),
            CompressorConfig::TopK { frac } => CompressorKind::TopK(TopK::new(dims, *frac)),
            CompressorConfig::Blocks(_) => panic!(
                "a layer-wise compressor needs the problem's BlockLayout; \
                 call CompressorConfig::build_for"
            ),
        }
    }

    /// Instantiate one sender-side compressor against the problem's
    /// [`BlockLayout`]. Flat schemes ignore the block structure and
    /// compress the whole `layout.dims()`-dimensional vector exactly as
    /// [`CompressorConfig::build`]; the layer-wise composition builds one
    /// inner compressor per block, in layout order. The spec must already
    /// satisfy [`CompressorConfig::validate_blocks`] — an unknown or
    /// missing block here is a caller bug and panics.
    pub fn build_for(&self, layout: &BlockLayout) -> CompressorKind {
        match self {
            CompressorConfig::Blocks(specs) => {
                let blocks = layout
                    .blocks()
                    .iter()
                    .map(|b| {
                        let (_, sub) = specs
                            .iter()
                            .find(|(n, _)| n == &b.name)
                            .unwrap_or_else(|| {
                                panic!(
                                    "layer spec is missing block {:?}; \
                                     call validate_blocks before build_for",
                                    b.name
                                )
                            });
                        (b.name.clone(), b.len, sub.build(b.len))
                    })
                    .collect();
                CompressorKind::Blocks(Box::new(BlockCompressor::new(blocks)))
            }
            flat => flat.build(layout.dims()),
        }
    }

    /// Check a layer-wise spec against the problem's [`BlockLayout`]: every
    /// named block must exist, and every layout block must be named. Flat
    /// schemes always validate. The error names the offending block *and*
    /// the valid set, so a typo'd `--compressor layers:...` is actionable.
    pub fn validate_blocks(&self, layout: &BlockLayout) -> Result<(), String> {
        let CompressorConfig::Blocks(specs) = self else {
            return Ok(());
        };
        for (name, _) in specs {
            if layout.get(name).is_none() {
                return Err(format!(
                    "layer spec names unknown block {name:?}; this problem's blocks: {}",
                    layout.names()
                ));
            }
        }
        for b in layout.blocks() {
            if !specs.iter().any(|(n, _)| n == &b.name) {
                return Err(format!(
                    "layer spec is missing block {:?}; this problem's blocks: {}",
                    b.name,
                    layout.names()
                ));
            }
        }
        Ok(())
    }
}

/// GADMM-family engine configuration.
#[derive(Clone, Debug)]
pub struct GadmmConfig {
    /// Number of workers N (paper: 50 linreg, 10 DNN).
    pub workers: usize,
    /// Disagreement penalty ρ (paper: 24 linreg, 20 DNN).
    pub rho: f32,
    /// Dual damping α: 1.0 for convex Q-GADMM (eq. (18)); 0.01 for
    /// Q-SGADMM (Sec. V-B).
    pub dual_step: f32,
    /// Per-link compression scheme (`compressor=` key / `--compressor`
    /// flag). `Stochastic` is Q-GADMM / Q-SGADMM; `FullPrecision` is
    /// GADMM / SGADMM; see [`CompressorConfig`] for the extended schemes.
    pub compressor: CompressorConfig,
    /// Engine threads for the head/tail phase executor: `0` = auto (use
    /// every core once a phase carries enough work to amortize spawning),
    /// `1` = strictly sequential, `t > 1` = always run phases on `t`
    /// scoped threads. Any value is bit-for-bit equivalent — per-position
    /// RNGs and disjoint phase writes make the schedule irrelevant to the
    /// result (asserted by `tests/engine_parallel_equivalence.rs`).
    pub threads: usize,
}

impl Default for GadmmConfig {
    fn default() -> Self {
        GadmmConfig {
            workers: 50,
            rho: 24.0,
            dual_step: 1.0,
            compressor: CompressorConfig::default(),
            threads: 0,
        }
    }
}

/// Parameter-server baseline configuration (GD/QGD/SGD/QSGD/ADIANA).
#[derive(Clone, Debug)]
pub struct PsConfig {
    pub workers: usize,
    /// Step size. `None` ⇒ auto-tune to 1/L from the data (GD-family).
    pub lr: Option<f64>,
    /// Quantize uplinks (QGD/QSGD/ADIANA).
    pub quant: Option<QuantConfig>,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig {
            workers: 50,
            lr: None,
            quant: None,
        }
    }
}

/// Wireless testbed configuration.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Deployment square side (m). Paper: 250.
    pub area_side: f64,
    pub channel: ChannelParams,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            area_side: 250.0,
            channel: ChannelParams::default(),
        }
    }
}

/// How the real-socket TCP driver (`net::tcp`) handles worker crashes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TcpFaultMode {
    /// Scheduled dropouts known to every worker up front — the simulator's
    /// fault model, reproduced bit-for-bit: every survivor applies the
    /// schedule at the same iteration boundary, so recovery needs no
    /// detection round-trips.
    #[default]
    Announced,
    /// Crash detection from socket EOF: the victim simply dies and the
    /// survivors converge on a common re-stitch iteration through shared
    /// cluster state. Recovers and converges, but the extra stale rounds
    /// mean it is not bit-pinned to the simulator.
    Detected,
}

impl TcpFaultMode {
    /// Parse a `tcp_faults=` value. The error names the invalid value and
    /// the valid set.
    pub fn parse(text: &str) -> Result<TcpFaultMode, String> {
        match text.trim() {
            "announced" | "scheduled" => Ok(TcpFaultMode::Announced),
            "detected" | "crash" => Ok(TcpFaultMode::Detected),
            other => Err(format!(
                "unknown tcp fault mode {other:?}; valid modes: announced, detected"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TcpFaultMode::Announced => "announced",
            TcpFaultMode::Detected => "detected",
        }
    }
}

/// Real-socket TCP driver configuration (`net::tcp`).
#[derive(Clone, Debug, PartialEq)]
pub struct TcpConfig {
    /// Multi-process mode: this process's listen address (`listen=` key /
    /// `--listen` flag). `None` (the default) runs every worker in one
    /// process over loopback listeners on ephemeral ports.
    pub listen: Option<String>,
    /// Multi-process mode: every worker's address in position order
    /// (`peers=` key / `--peers` flag, comma-separated). Must include the
    /// `listen` address, which selects the hosted position.
    pub peers: Vec<String>,
    /// Dial/receive deadline in milliseconds (`tcp_timeout_ms=` key).
    pub timeout_ms: u64,
    /// How worker crashes are handled (`tcp_faults=` key).
    pub fault_mode: TcpFaultMode,
}

impl Default for TcpConfig {
    fn default() -> Self {
        TcpConfig {
            listen: None,
            peers: Vec::new(),
            timeout_ms: 60_000,
            fault_mode: TcpFaultMode::Announced,
        }
    }
}

impl TcpConfig {
    /// Parse a comma/semicolon-separated `peers=` list, validating each
    /// entry as a socket address.
    pub fn parse_peers(text: &str) -> Result<Vec<String>, String> {
        let mut out = Vec::new();
        for part in text.split([',', ';']) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            part.parse::<std::net::SocketAddr>()
                .map_err(|_| format!("bad peer address {part:?} (want ip:port)"))?;
            out.push(part.to_string());
        }
        if out.is_empty() {
            return Err("peers list is empty; want ip:port,ip:port,...".to_string());
        }
        Ok(out)
    }
}

/// One scheduled worker failure for the fault-injection scenarios.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dropout {
    /// Worker id that disappears.
    pub worker: usize,
    /// Iteration (1-based) at whose start the worker is gone; the chain is
    /// re-stitched over the survivors before that iteration runs.
    pub at_iteration: u64,
}

/// Gilbert–Elliott burst-loss parameters (the good-state loss probability
/// is [`SimConfig::loss`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BurstParams {
    /// Per-frame good→bad transition probability.
    pub to_bad: f64,
    /// Per-frame bad→good transition probability.
    pub to_good: f64,
    /// Loss probability while in the bad state.
    pub loss_bad: f64,
}

impl Default for BurstParams {
    fn default() -> Self {
        BurstParams {
            to_bad: 0.05,
            to_good: 0.25,
            loss_bad: 1.0,
        }
    }
}

/// Discrete-event simulator configuration (`coordinator::simulated`).
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Frame loss probability (Bernoulli; with [`Self::burst`] set, the
    /// good-state loss probability of the Gilbert–Elliott chain).
    pub loss: f64,
    /// Enable bursty Gilbert–Elliott loss instead of iid loss.
    pub burst: Option<BurstParams>,
    /// Link serialization rate in bit/s (`<= 0` ⇒ instantaneous).
    pub link_rate_bps: f64,
    /// Fixed per-frame overhead in seconds (MAC, processing).
    pub per_frame_overhead_secs: f64,
    /// Propagation delay per meter of link distance (s/m).
    pub prop_secs_per_m: f64,
    /// Mean local-solve time per iteration in seconds.
    pub compute_mean_secs: f64,
    /// Exponential-jitter fraction of the solve time, in `[0, 1]`.
    pub compute_jitter: f64,
    /// Number of straggler workers (the highest worker ids).
    pub stragglers: usize,
    /// Compute-time multiplier applied to stragglers.
    pub straggler_factor: f64,
    /// ARQ attempt cap per frame; past it the frame is abandoned and the
    /// receiver's mirror goes stale for the round.
    pub max_attempts: u32,
    /// Retransmission timeout charged per lost attempt (seconds).
    pub arq_timeout_secs: f64,
    /// Scheduled worker failures.
    pub dropouts: Vec<Dropout>,
    /// Seed for all simulator-side randomness (link loss, compute jitter);
    /// the *model* randomness keeps the engine's seed so loss-free runs
    /// are bit-identical to `GadmmEngine`.
    pub seed: u64,
    /// Record the full event trace (determinism tests, debugging).
    pub record_trace: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            loss: 0.0,
            burst: None,
            link_rate_bps: 1e6,
            per_frame_overhead_secs: 1e-3,
            prop_secs_per_m: 1.0 / 2.998e8,
            compute_mean_secs: 2e-3,
            compute_jitter: 0.2,
            stragglers: 0,
            straggler_factor: 4.0,
            max_attempts: 8,
            arq_timeout_secs: 2e-3,
            dropouts: Vec::new(),
            seed: 7,
            record_trace: false,
        }
    }
}

impl SimConfig {
    /// The idealized-network limit: no loss, zero latency, zero compute
    /// time. In this configuration `coordinator::simulated` reproduces
    /// `GadmmEngine` bit-for-bit (see the `sim_determinism` suite).
    pub fn ideal() -> SimConfig {
        SimConfig {
            loss: 0.0,
            burst: None,
            link_rate_bps: 0.0,
            per_frame_overhead_secs: 0.0,
            prop_secs_per_m: 0.0,
            compute_mean_secs: 0.0,
            compute_jitter: 0.0,
            stragglers: 0,
            straggler_factor: 1.0,
            max_attempts: 1,
            arq_timeout_secs: 0.0,
            dropouts: Vec::new(),
            seed: 7,
            record_trace: false,
        }
    }

    pub fn loss_model(&self) -> LossModel {
        match self.burst {
            Some(b) => LossModel::GilbertElliott {
                to_bad: b.to_bad,
                to_good: b.to_good,
                loss_good: self.loss.clamp(0.0, 1.0),
                loss_bad: b.loss_bad,
            },
            None => LossModel::bernoulli(self.loss),
        }
    }

    pub fn latency_model(&self) -> LatencyModel {
        LatencyModel {
            rate_bps: self.link_rate_bps,
            per_frame_secs: self.per_frame_overhead_secs,
            prop_secs_per_m: self.prop_secs_per_m,
        }
    }

    pub fn compute_model(&self) -> ComputeModel {
        ComputeModel {
            mean_secs: self.compute_mean_secs,
            jitter: self.compute_jitter,
        }
    }

    /// Straggler factor for worker `id` out of `n`: the `stragglers`
    /// highest ids run `straggler_factor`× slower.
    pub fn compute_scale(&self, id: usize, n: usize) -> f64 {
        if self.stragglers > 0 && id + self.stragglers >= n {
            self.straggler_factor.max(1.0)
        } else {
            1.0
        }
    }

    /// Parse a dropout schedule of the form `"3@50,7@120"` (worker 3 drops
    /// before iteration 50, worker 7 before iteration 120). `;` also
    /// separates entries.
    pub fn parse_dropouts(text: &str) -> Result<Vec<Dropout>, String> {
        let mut out = Vec::new();
        for part in text.split([',', ';']) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (w, k) = part
                .split_once('@')
                .ok_or_else(|| format!("bad dropout {part:?} (want worker@iteration)"))?;
            let worker = w
                .trim()
                .parse::<usize>()
                .map_err(|_| format!("bad dropout worker in {part:?}"))?;
            let at_iteration = k
                .trim()
                .parse::<u64>()
                .map_err(|_| format!("bad dropout iteration in {part:?}"))?;
            out.push(Dropout {
                worker,
                at_iteration,
            });
        }
        Ok(out)
    }
}

/// Top-level experiment description used by the CLI and figure harness.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub gadmm: GadmmConfig,
    pub net: NetConfig,
    /// Which local problem the Session trains (`problem=` key /
    /// `--problem` flag): `linreg` (default), `diag-linreg`, `mlp`,
    /// `logreg`.
    pub problem: ProblemKind,
    /// Which runtime drives the run (`driver=` key / `--driver` flag):
    /// `engine` (default), `threaded`, `sim`.
    pub driver: DriverKind,
    /// Metric evaluation cadence override (`eval_every=` key). `None`
    /// resolves to the problem's default (1 for linreg/logreg, 5 for the
    /// DNN, 10 for the scale task).
    pub eval_every: Option<u64>,
    /// Communication graph for `train-*` and `simulate` (`topology=` key /
    /// `--topology` flag): `line` (default), `ring`, `star`, `grid2d`, or
    /// `random[:p]`. Geometry-driven figure runs keep the nearest-neighbor
    /// chain when this is `Line`.
    pub topology: TopologyKind,
    /// Discrete-event simulator settings (the `simulate` subcommand and
    /// `figures::fig_sim`).
    pub sim: SimConfig,
    /// Real-socket TCP driver settings (`--driver tcp`).
    pub tcp: TcpConfig,
    /// How ρ evolves across iterations (`rho_policy=` key / `--rho_policy`
    /// flag): `fixed` (default, the paper's setting) or
    /// `residual-balance[:mu[:tau_incr[:tau_decr]]]` (Boyd §3.4.1
    /// balancing computed from the per-iteration residual snapshot; every
    /// driver applies the same deterministic rule).
    pub rho_policy: RhoPolicy,
    /// Max iterations per run.
    pub iterations: u64,
    /// Loss-gap target (linreg figures).
    pub loss_target: f64,
    /// Accuracy target (DNN figures).
    pub accuracy_target: f64,
    /// Number of random drops for the CDF figures.
    pub drops: usize,
    /// Model dimension of the `train-scale` scenario (diagonal-Gram
    /// linreg, `model::scale`).
    pub scale_dims: usize,
    /// Base seed.
    pub seed: u64,
    /// Output directory for reports.
    pub results_dir: String,
    /// Execute local solves through the PJRT artifacts instead of the
    /// native backend (requires `make artifacts`).
    pub use_xla: bool,
    /// Write the structured telemetry stream as JSON Lines to this path
    /// (`trace = <path>` key / `--trace <path>` flag). `None` disables
    /// the exporter.
    pub trace_jsonl: Option<String>,
    /// Write a Chrome trace-event JSON file to this path
    /// (`chrome_trace = <path>` key / `--chrome_trace <path>` flag) —
    /// loadable in `chrome://tracing` or Perfetto.
    pub chrome_trace: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            gadmm: GadmmConfig::default(),
            net: NetConfig::default(),
            problem: ProblemKind::LinReg,
            driver: DriverKind::Engine,
            eval_every: None,
            topology: TopologyKind::Line,
            sim: SimConfig::default(),
            tcp: TcpConfig::default(),
            rho_policy: RhoPolicy::Fixed,
            iterations: 2_000,
            loss_target: 1e-4,
            accuracy_target: 0.90,
            drops: 20,
            scale_dims: 10_000,
            seed: 1,
            results_dir: "results".to_string(),
            use_xla: false,
            trace_jsonl: None,
            chrome_trace: None,
        }
    }
}

impl ExperimentConfig {
    /// Apply `key = value` overrides (from file or CLI).
    pub fn apply_kv(&mut self, kv: &KvMap) -> Result<(), ConfigError> {
        for (k, v) in kv.iter() {
            self.apply_one(k, v)?;
        }
        Ok(())
    }

    fn apply_one(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        let bad = |why: &str| ConfigError::BadValue {
            key: key.to_string(),
            value: value.to_string(),
            why: why.to_string(),
        };
        match key {
            "workers" => self.gadmm.workers = value.parse().map_err(|_| bad("usize"))?,
            "rho" => self.gadmm.rho = value.parse().map_err(|_| bad("f32"))?,
            "dual_step" | "dual-step" | "alpha" => {
                self.gadmm.dual_step = value.parse().map_err(|_| bad("f32"))?
            }
            "bits" => {
                let bits: u8 = value.parse().map_err(|_| bad("u8"))?;
                // bits=0 means full precision; otherwise set the quantizer
                // width of the current scheme.
                self.gadmm.compressor =
                    self.gadmm.compressor.clone().with_bits(bits).map_err(|why| bad(&why))?;
            }
            "adaptive_bits" | "adaptive-bits" => {
                let adaptive: bool = value.parse().map_err(|_| bad("bool"))?;
                self.gadmm.compressor = self
                    .gadmm
                    .compressor
                    .clone()
                    .with_adaptive(adaptive)
                    .map_err(|why| bad(&why))?;
            }
            "compressor" | "comp" | "scheme" => {
                let base = self.gadmm.compressor.quant().unwrap_or_default();
                self.gadmm.compressor =
                    CompressorConfig::parse(value, base).map_err(|why| bad(&why))?;
            }
            "rho_policy" | "rho-policy" => {
                self.rho_policy = RhoPolicy::parse(value).map_err(|why| bad(&why))?
            }
            "iterations" | "iters" => {
                self.iterations = value.parse().map_err(|_| bad("u64"))?
            }
            "problem" | "task" => {
                self.problem = ProblemKind::parse(value).map_err(|why| bad(&why))?
            }
            "driver" | "runtime" => {
                self.driver = DriverKind::parse(value).map_err(|why| bad(&why))?
            }
            "eval_every" | "eval-every" => {
                let k: u64 = value.parse().map_err(|_| bad("u64"))?;
                if k == 0 {
                    return Err(bad("eval cadence >= 1"));
                }
                self.eval_every = Some(k);
            }
            "loss_target" | "loss-target" => self.loss_target = value.parse().map_err(|_| bad("f64"))?,
            "accuracy_target" | "accuracy-target" => {
                self.accuracy_target = value.parse().map_err(|_| bad("f64"))?
            }
            "drops" => self.drops = value.parse().map_err(|_| bad("usize"))?,
            "threads" => {
                let t: usize = value.parse().map_err(|_| bad("usize"))?;
                if t > 4096 {
                    return Err(bad("thread count in 0..=4096 (0 = auto)"));
                }
                self.gadmm.threads = t;
            }
            "dims" | "scale_dims" | "scale-dims" => {
                let d: usize = value.parse().map_err(|_| bad("usize"))?;
                if d == 0 {
                    return Err(bad("positive model dimension"));
                }
                self.scale_dims = d;
            }
            "topology" | "topo" => {
                self.topology = TopologyKind::parse(value).map_err(|why| bad(&why))?
            }
            "seed" => self.seed = value.parse().map_err(|_| bad("u64"))?,
            "results_dir" | "results-dir" | "out" => self.results_dir = value.to_string(),
            "use_xla" | "use-xla" => self.use_xla = value.parse().map_err(|_| bad("bool"))?,
            "bandwidth_mhz" | "bandwidth-mhz" => {
                self.net.channel.total_bandwidth_hz =
                    value.parse::<f64>().map_err(|_| bad("f64"))? * 1e6
            }
            "slot_ms" | "slot-ms" => {
                self.net.channel.slot_secs =
                    value.parse::<f64>().map_err(|_| bad("f64"))? * 1e-3
            }
            "area_side" | "area-side" => self.net.area_side = value.parse().map_err(|_| bad("f64"))?,
            "loss" => {
                let p: f64 = value.parse().map_err(|_| bad("f64"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(bad("probability in [0, 1]"));
                }
                self.sim.loss = p;
            }
            "ge_to_bad" | "ge-to-bad" => {
                let p: f64 = value.parse().map_err(|_| bad("f64"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(bad("probability in [0, 1]"));
                }
                let mut b = self.sim.burst.unwrap_or_default();
                b.to_bad = p;
                self.sim.burst = Some(b);
            }
            "ge_to_good" | "ge-to-good" => {
                let p: f64 = value.parse().map_err(|_| bad("f64"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(bad("probability in [0, 1]"));
                }
                let mut b = self.sim.burst.unwrap_or_default();
                b.to_good = p;
                self.sim.burst = Some(b);
            }
            "ge_loss_bad" | "ge-loss-bad" => {
                let p: f64 = value.parse().map_err(|_| bad("f64"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(bad("probability in [0, 1]"));
                }
                let mut b = self.sim.burst.unwrap_or_default();
                b.loss_bad = p;
                self.sim.burst = Some(b);
            }
            "link_rate_mbps" | "link-rate-mbps" => {
                self.sim.link_rate_bps =
                    value.parse::<f64>().map_err(|_| bad("f64"))? * 1e6
            }
            "frame_overhead_ms" | "frame-overhead-ms" => {
                self.sim.per_frame_overhead_secs =
                    value.parse::<f64>().map_err(|_| bad("f64"))? * 1e-3
            }
            "compute_ms" | "compute-ms" => {
                self.sim.compute_mean_secs =
                    value.parse::<f64>().map_err(|_| bad("f64"))? * 1e-3
            }
            "compute_jitter" | "compute-jitter" => {
                self.sim.compute_jitter = value.parse().map_err(|_| bad("f64"))?
            }
            "stragglers" => self.sim.stragglers = value.parse().map_err(|_| bad("usize"))?,
            "straggler_factor" | "straggler-factor" => {
                self.sim.straggler_factor = value.parse().map_err(|_| bad("f64"))?
            }
            "max_attempts" | "max-attempts" => {
                self.sim.max_attempts = value.parse().map_err(|_| bad("u32"))?
            }
            "arq_timeout_ms" | "arq-timeout-ms" => {
                self.sim.arq_timeout_secs =
                    value.parse::<f64>().map_err(|_| bad("f64"))? * 1e-3
            }
            "sim_seed" | "sim-seed" => self.sim.seed = value.parse().map_err(|_| bad("u64"))?,
            "listen" => {
                value
                    .parse::<std::net::SocketAddr>()
                    .map_err(|_| bad("listen socket address (ip:port)"))?;
                self.tcp.listen = Some(value.to_string());
            }
            "peers" => {
                self.tcp.peers = TcpConfig::parse_peers(value).map_err(|why| bad(&why))?
            }
            "tcp_timeout_ms" | "tcp-timeout-ms" => {
                let ms: u64 = value.parse().map_err(|_| bad("u64"))?;
                if ms == 0 {
                    return Err(bad("timeout >= 1 ms"));
                }
                self.tcp.timeout_ms = ms;
            }
            "tcp_faults" | "tcp-faults" => {
                self.tcp.fault_mode = TcpFaultMode::parse(value).map_err(|why| bad(&why))?
            }
            "dropouts" | "drop" => {
                self.sim.dropouts =
                    SimConfig::parse_dropouts(value).map_err(|why| bad(&why))?
            }
            // `trace` is overloaded for compatibility: a boolean keeps its
            // original meaning (record the simulator's TraceEvent list);
            // any other value is a JSONL telemetry output path, so the
            // bare `--trace` flag (→ "true") and `--trace out.jsonl` both
            // parse.
            "trace" => match value.parse::<bool>() {
                Ok(b) => self.sim.record_trace = b,
                Err(_) => self.trace_jsonl = Some(value.to_string()),
            },
            "chrome_trace" | "chrome-trace" => {
                self.chrome_trace = Some(value.to_string())
            }
            _ => {
                return Err(ConfigError::UnknownKey {
                    key: key.to_string(),
                })
            }
        }
        Ok(())
    }
}

/// Ordered string→string map parsed from `key = value` lines.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvMap {
    entries: BTreeMap<String, String>,
}

impl KvMap {
    pub fn new() -> KvMap {
        KvMap::default()
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.entries.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Parse config text: `key = value` per line, `#` comments, blank lines
    /// and `[section]` headers ignored (sections exist for human grouping).
    pub fn parse(text: &str) -> Result<KvMap, ConfigError> {
        let mut map = KvMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(ConfigError::Syntax {
                    line: lineno + 1,
                    text: raw.to_string(),
                });
            };
            let key = k.trim();
            let val = v.trim().trim_matches('"');
            if key.is_empty() {
                return Err(ConfigError::Syntax {
                    line: lineno + 1,
                    text: raw.to_string(),
                });
            }
            map.set(key, val);
        }
        Ok(map)
    }
}

/// Configuration errors.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("config syntax error on line {line}: {text:?}")]
    Syntax { line: usize, text: String },
    #[error("unknown config key {key:?}")]
    UnknownKey { key: String },
    #[error("bad value for {key:?}: {value:?} (expected {why})")]
    BadValue {
        key: String,
        value: String,
        why: String,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kv_file() {
        let text = r#"
            # experiment
            [run]
            workers = 10
            rho = 12.5
            bits = 2
            results_dir = "out/run1"
        "#;
        let kv = KvMap::parse(text).unwrap();
        assert_eq!(kv.get("workers"), Some("10"));
        assert_eq!(kv.get("results_dir"), Some("out/run1"));

        let mut cfg = ExperimentConfig::default();
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.workers, 10);
        assert_eq!(cfg.gadmm.rho, 12.5);
        assert_eq!(cfg.gadmm.compressor.quant().unwrap().bits, 2);
        assert_eq!(cfg.results_dir, "out/run1");
    }

    #[test]
    fn bits_zero_disables_quantization() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("bits", "0");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.compressor, CompressorConfig::FullPrecision);
        // And bits=N promotes it back to stochastic.
        let mut kv = KvMap::new();
        kv.set("bits", "4");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.compressor.quant().unwrap().bits, 4);
        assert_eq!(cfg.gadmm.compressor.name(), "stochastic");
    }

    #[test]
    fn compressor_key_parses_every_scheme() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("compressor", "full");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.compressor, CompressorConfig::FullPrecision);

        let mut kv = KvMap::new();
        kv.set("compressor", "stochastic");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(
            cfg.gadmm.compressor,
            CompressorConfig::Stochastic(QuantConfig::default())
        );

        let mut kv = KvMap::new();
        kv.set("compressor", "censored:0.1:0.99");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(
            cfg.gadmm.compressor,
            CompressorConfig::Censored {
                quant: QuantConfig::default(),
                tau0: 0.1,
                decay: 0.99
            }
        );

        let mut kv = KvMap::new();
        kv.set("compressor", "topk:0.05");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.compressor, CompressorConfig::TopK { frac: 0.05 });

        // Defaults when parameters are omitted.
        let mut kv = KvMap::new();
        kv.set("compressor", "censored");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(
            cfg.gadmm.compressor,
            CompressorConfig::Censored {
                quant: QuantConfig::default(),
                tau0: CENSOR_TAU0,
                decay: CENSOR_DECAY
            }
        );
        let mut kv = KvMap::new();
        kv.set("compressor", "topk");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.compressor, CompressorConfig::TopK { frac: TOPK_FRAC });
    }

    #[test]
    fn compressor_bits_compose_regardless_of_order() {
        // A KvMap applies keys alphabetically (bits before compressor), and
        // the CLI applies its overrides in a second pass — both orders must
        // land on the same config.
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("bits", "8");
        kv.set("compressor", "censored:0.2");
        cfg.apply_kv(&kv).unwrap();
        match &cfg.gadmm.compressor {
            CompressorConfig::Censored { quant, tau0, .. } => {
                assert_eq!(quant.bits, 8);
                assert_eq!(*tau0, 0.2);
            }
            other => panic!("expected censored, got {other:?}"),
        }
        // Second pass: bits applied after the scheme is already censored.
        let mut kv = KvMap::new();
        kv.set("bits", "3");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.compressor.quant().unwrap().bits, 3);
        assert_eq!(cfg.gadmm.compressor.name(), "censored");
    }

    #[test]
    fn unknown_scheme_is_a_typed_error_naming_the_valid_set() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("compressor", "middle-out");
        let err = cfg.apply_kv(&kv).unwrap_err();
        match &err {
            ConfigError::BadValue { key, value, why } => {
                assert_eq!(key, "compressor");
                assert_eq!(value, "middle-out");
                assert!(why.contains("middle-out"), "must name the unknown scheme: {why}");
                assert!(
                    why.contains("stochastic") && why.contains("censored") && why.contains("topk"),
                    "must list the valid schemes: {why}"
                );
            }
            other => panic!("expected BadValue, got {other:?}"),
        }
        // The config is left untouched — no silent default.
        assert_eq!(cfg.gadmm.compressor, CompressorConfig::default());
    }

    #[test]
    fn malformed_scheme_parameters_are_rejected() {
        let mut cfg = ExperimentConfig::default();
        for bad in [
            "topk:0",
            "topk:1.5",
            "topk:lots",
            "topk:0.1:2",
            "censored:-1",
            "censored:0.1:0",
            "censored:0.1:1.5",
            "censored:a:b",
            "censored:0.1:0.9:7",
            "full:3",
            "stochastic:2",
        ] {
            let mut kv = KvMap::new();
            kv.set("compressor", bad);
            assert!(
                matches!(cfg.apply_kv(&kv), Err(ConfigError::BadValue { .. })),
                "{bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn bits_on_topk_is_rejected() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("compressor", "topk");
        cfg.apply_kv(&kv).unwrap();
        let mut kv = KvMap::new();
        kv.set("bits", "2");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
        let mut kv = KvMap::new();
        kv.set("adaptive_bits", "true");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
        // bits=0 (full precision) is always legal.
        let mut kv = KvMap::new();
        kv.set("bits", "0");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.compressor, CompressorConfig::FullPrecision);
    }

    #[test]
    fn xla_compatibility_is_scheme_gated() {
        assert!(CompressorConfig::default().xla_compatible());
        assert!(CompressorConfig::FullPrecision.xla_compatible());
        assert!(!CompressorConfig::TopK { frac: 0.1 }.xla_compatible());
        assert!(!CompressorConfig::Censored {
            quant: QuantConfig::default(),
            tau0: 0.1,
            decay: 0.99
        }
        .xla_compatible());
    }

    #[test]
    fn compressor_builds_matching_kind() {
        use crate::quant::Compressor as _;
        let d = 8;
        for (cfg, name) in [
            (CompressorConfig::FullPrecision, "full"),
            (CompressorConfig::default(), "stochastic"),
            (
                CompressorConfig::Censored {
                    quant: QuantConfig::default(),
                    tau0: 0.1,
                    decay: 0.99,
                },
                "censored",
            ),
            (CompressorConfig::TopK { frac: 0.25 }, "topk"),
        ] {
            let kind = cfg.build(d);
            assert_eq!(kind.name(), name);
            assert_eq!(kind.dims(), d);
            assert_eq!(cfg.name(), name);
        }
    }

    #[test]
    fn layers_spec_parses_per_block() {
        let base = QuantConfig::default();
        let cfg = CompressorConfig::parse("layers:w1=stochastic@4, w2=topk:0.1, w3=full", base)
            .unwrap();
        assert_eq!(cfg.name(), "layers");
        assert_eq!(cfg.quant(), None);
        assert!(!cfg.xla_compatible());
        let CompressorConfig::Blocks(specs) = &cfg else {
            panic!("expected layers, got {cfg:?}");
        };
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].0, "w1");
        assert_eq!(
            specs[0].1,
            CompressorConfig::Stochastic(QuantConfig { bits: 4, ..base })
        );
        assert_eq!(specs[1].0, "w2");
        assert_eq!(specs[1].1, CompressorConfig::TopK { frac: 0.1 });
        assert_eq!(specs[2].0, "w3");
        assert_eq!(specs[2].1, CompressorConfig::FullPrecision);
        // Blocks without @bits inherit the base width (so --bits composes).
        let wide = QuantConfig {
            bits: 8,
            ..QuantConfig::default()
        };
        let cfg = CompressorConfig::parse("layers:w1=stochastic", wide).unwrap();
        let CompressorConfig::Blocks(specs) = &cfg else {
            panic!("expected layers");
        };
        assert_eq!(specs[0].1, CompressorConfig::Stochastic(wide));
    }

    #[test]
    fn uniform_is_the_flat_default() {
        let base = QuantConfig {
            bits: 8,
            ..QuantConfig::default()
        };
        // `uniform` alone is the default stochastic scheme over the whole
        // vector — the exact pre-layers config, bit-for-bit.
        assert_eq!(
            CompressorConfig::parse("uniform", base).unwrap(),
            CompressorConfig::Stochastic(base)
        );
        // `uniform:<scheme>` is the flat parse of <scheme>.
        assert_eq!(
            CompressorConfig::parse("uniform:censored:0.1", base).unwrap(),
            CompressorConfig::parse("censored:0.1", base).unwrap()
        );
        assert_eq!(
            CompressorConfig::parse("uniform:full", base).unwrap(),
            CompressorConfig::FullPrecision
        );
    }

    #[test]
    fn malformed_layer_specs_are_rejected() {
        let base = QuantConfig::default();
        for bad in [
            "layers",
            "layers:",
            "layers: , ,",
            "layers:w1",
            "layers:=full",
            "layers:w1=stochastic,w1=full",
            "layers:w1=layers",
            "layers:w1=uniform",
            "layers:w1=full@4",
            "layers:w1=topk@2:0.1",
            "layers:w1=stochastic@0",
            "layers:w1=stochastic@lots",
            "layers:w1=middle-out",
            "layers:w1=topk:2",
        ] {
            assert!(
                CompressorConfig::parse(bad, base).is_err(),
                "{bad:?} must be rejected"
            );
        }
        // And via the kv layer the error is typed, config untouched.
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("compressor", "layers:w1=stochastic,w1=full");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
        assert_eq!(cfg.gadmm.compressor, CompressorConfig::default());
    }

    #[test]
    fn bits_keys_are_rejected_on_layers() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("compressor", "layers:all=stochastic@4");
        cfg.apply_kv(&kv).unwrap();
        let mut kv = KvMap::new();
        kv.set("bits", "2");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
        let mut kv = KvMap::new();
        kv.set("adaptive_bits", "true");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
        // The layers config survives the rejected overrides.
        assert_eq!(cfg.gadmm.compressor.name(), "layers");
    }

    #[test]
    fn tcp_keys_parse_and_reject() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.tcp, TcpConfig::default());
        assert_eq!(cfg.tcp.timeout_ms, 60_000);

        let mut kv = KvMap::new();
        kv.set("listen", "127.0.0.1:7001");
        kv.set("peers", "127.0.0.1:7000, 127.0.0.1:7001; 127.0.0.1:7002");
        kv.set("tcp_timeout_ms", "5000");
        kv.set("tcp_faults", "detected");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.tcp.listen.as_deref(), Some("127.0.0.1:7001"));
        assert_eq!(
            cfg.tcp.peers,
            vec!["127.0.0.1:7000", "127.0.0.1:7001", "127.0.0.1:7002"]
        );
        assert_eq!(cfg.tcp.timeout_ms, 5000);
        assert_eq!(cfg.tcp.fault_mode, TcpFaultMode::Detected);

        // Every malformed value is a typed BadValue, never a silent default.
        for (key, value) in [
            ("listen", "not-an-address"),
            ("listen", "127.0.0.1"),
            ("peers", "127.0.0.1:7000,nope"),
            ("peers", " , "),
            ("tcp_timeout_ms", "0"),
            ("tcp_timeout_ms", "soon"),
            ("tcp_faults", "psychic"),
        ] {
            let mut kv = KvMap::new();
            kv.set(key, value);
            let err = cfg.apply_kv(&kv).unwrap_err();
            assert!(
                matches!(err, ConfigError::BadValue { .. }),
                "{key}={value} must be a BadValue, got {err:?}"
            );
        }
        // And the fault-mode error names the value and the valid set.
        let err = TcpFaultMode::parse("psychic").unwrap_err();
        assert!(err.contains("psychic") && err.contains("announced") && err.contains("detected"));
    }

    #[test]
    fn validate_blocks_checks_names_and_coverage() {
        let layout = BlockLayout::new(vec![("w1", 4), ("w2", 2)]);
        let base = QuantConfig::default();
        let ok = CompressorConfig::parse("layers:w1=stochastic,w2=full", base).unwrap();
        ok.validate_blocks(&layout).unwrap();

        let unknown = CompressorConfig::parse("layers:w1=stochastic,wz=full", base).unwrap();
        let err = unknown.validate_blocks(&layout).unwrap_err();
        assert!(err.contains("\"wz\""), "must name the unknown block: {err}");
        assert!(err.contains("w1, w2"), "must list the valid blocks: {err}");

        let missing = CompressorConfig::parse("layers:w1=stochastic", base).unwrap();
        let err = missing.validate_blocks(&layout).unwrap_err();
        assert!(err.contains("\"w2\""), "must name the missing block: {err}");

        // Flat schemes validate against any layout.
        CompressorConfig::FullPrecision.validate_blocks(&layout).unwrap();
        CompressorConfig::default().validate_blocks(&layout).unwrap();
    }

    #[test]
    fn build_for_composes_per_block_compressors() {
        use crate::quant::Compressor as _;
        let layout = BlockLayout::new(vec![("w1", 4), ("w2", 2)]);
        let base = QuantConfig::default();
        let cfg = CompressorConfig::parse("layers:w1=stochastic@4,w2=full", base).unwrap();
        let kind = cfg.build_for(&layout);
        assert_eq!(kind.name(), "layers");
        assert_eq!(kind.dims(), 6);
        let blocks = kind.as_blocks().expect("layers kind").blocks();
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].name(), "w1");
        assert_eq!((blocks[0].offset(), blocks[0].len()), (0, 4));
        assert_eq!(blocks[1].name(), "w2");
        assert_eq!((blocks[1].offset(), blocks[1].len()), (4, 2));
        // Flat configs ignore the block structure entirely.
        let flat = CompressorConfig::default().build_for(&layout);
        assert_eq!(flat.name(), "stochastic");
        assert_eq!(flat.dims(), 6);
    }

    #[test]
    fn rho_policy_key_parses_and_rejects() {
        use crate::coordinator::residuals::RhoPolicy;
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.rho_policy, RhoPolicy::Fixed, "fixed is the default");
        let mut kv = KvMap::new();
        kv.set("rho_policy", "residual-balance:5");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(
            cfg.rho_policy,
            RhoPolicy::ResidualBalance {
                mu: 5.0,
                tau_incr: 2.0,
                tau_decr: 2.0
            }
        );
        let mut kv = KvMap::new();
        kv.set("rho_policy", "annealed");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
    }

    #[test]
    fn legacy_quant_option_conversion() {
        assert_eq!(
            CompressorConfig::from(None::<QuantConfig>),
            CompressorConfig::FullPrecision
        );
        let q = QuantConfig {
            bits: 8,
            ..QuantConfig::default()
        };
        assert_eq!(
            CompressorConfig::from(Some(q)),
            CompressorConfig::Stochastic(q)
        );
    }

    #[test]
    fn rejects_unknown_key_and_bad_value() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("wurkers", "10");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::UnknownKey { .. })
        ));
        let mut kv2 = KvMap::new();
        kv2.set("workers", "ten");
        assert!(matches!(
            cfg.apply_kv(&kv2),
            Err(ConfigError::BadValue { .. })
        ));
    }

    #[test]
    fn rejects_syntax_errors() {
        assert!(KvMap::parse("just words\n").is_err());
        assert!(KvMap::parse(" = novalue\n").is_err());
        assert!(KvMap::parse("# fine\n[ok]\na = 1\n").is_ok());
    }

    #[test]
    fn threads_and_scale_dims_keys() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.gadmm.threads, 0, "default is auto");
        let mut kv = KvMap::new();
        kv.set("threads", "4");
        kv.set("dims", "2048");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.threads, 4);
        assert_eq!(cfg.scale_dims, 2048);

        let mut kv = KvMap::new();
        kv.set("threads", "9999999");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
        let mut kv = KvMap::new();
        kv.set("dims", "0");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
    }

    #[test]
    fn problem_driver_and_eval_keys() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.problem, ProblemKind::LinReg);
        assert_eq!(cfg.driver, DriverKind::Engine);
        assert_eq!(cfg.eval_every, None);

        let mut kv = KvMap::new();
        kv.set("problem", "logreg");
        kv.set("driver", "sim");
        kv.set("eval_every", "5");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.problem, ProblemKind::LogReg);
        assert_eq!(cfg.driver, DriverKind::Sim);
        assert_eq!(cfg.eval_every, Some(5));

        for (key, bad_value) in [
            ("problem", "svm"),
            ("driver", "gpu"),
            ("eval_every", "0"),
            ("eval_every", "often"),
        ] {
            let mut kv = KvMap::new();
            kv.set(key, bad_value);
            assert!(
                matches!(cfg.apply_kv(&kv), Err(ConfigError::BadValue { .. })),
                "{key}={bad_value} must be rejected"
            );
        }
    }

    #[test]
    fn topology_key_parses_and_rejects() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.topology, TopologyKind::Line, "chain is the default");
        let mut kv = KvMap::new();
        kv.set("topology", "ring");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.topology, TopologyKind::Ring);

        let mut kv = KvMap::new();
        kv.set("topology", "random:0.4");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.topology, TopologyKind::RandomBipartite { p: 0.4 });

        let mut kv = KvMap::new();
        kv.set("topology", "hier:10:ring");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(
            cfg.topology,
            TopologyKind::Hier {
                groups: 10,
                inner: crate::net::hier::InnerKind::Ring
            }
        );

        let mut kv = KvMap::new();
        kv.set("topology", "hexagon");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));

        // The hier grammar rejects malformed group counts through the same
        // typed error path.
        let mut kv = KvMap::new();
        kv.set("topology", "hier:zero");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
    }

    #[test]
    fn bandwidth_in_mhz() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("bandwidth_mhz", "40");
        kv.set("slot_ms", "100");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.net.channel.total_bandwidth_hz, 40e6);
        assert_eq!(cfg.net.channel.slot_secs, 0.1);
    }

    #[test]
    fn trace_key_is_bool_or_jsonl_path() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("trace", "true");
        cfg.apply_kv(&kv).unwrap();
        assert!(cfg.sim.record_trace);
        assert_eq!(cfg.trace_jsonl, None);

        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("trace", "run.jsonl");
        kv.set("chrome_trace", "run.chrome.json");
        cfg.apply_kv(&kv).unwrap();
        assert!(!cfg.sim.record_trace);
        assert_eq!(cfg.trace_jsonl.as_deref(), Some("run.jsonl"));
        assert_eq!(cfg.chrome_trace.as_deref(), Some("run.chrome.json"));
    }

    #[test]
    fn sim_keys_apply() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("loss", "0.15");
        kv.set("link_rate_mbps", "2");
        kv.set("compute_ms", "5");
        kv.set("stragglers", "2");
        kv.set("straggler_factor", "8");
        kv.set("max_attempts", "4");
        kv.set("dropouts", "3@50, 7@120");
        kv.set("trace", "true");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.sim.loss, 0.15);
        assert_eq!(cfg.sim.link_rate_bps, 2e6);
        assert_eq!(cfg.sim.compute_mean_secs, 5e-3);
        assert_eq!(cfg.sim.stragglers, 2);
        assert_eq!(cfg.sim.straggler_factor, 8.0);
        assert_eq!(cfg.sim.max_attempts, 4);
        assert_eq!(
            cfg.sim.dropouts,
            vec![
                Dropout {
                    worker: 3,
                    at_iteration: 50
                },
                Dropout {
                    worker: 7,
                    at_iteration: 120
                }
            ]
        );
        assert!(cfg.sim.record_trace);

        let mut kv = KvMap::new();
        kv.set("loss", "1.5");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
        let mut kv = KvMap::new();
        kv.set("dropouts", "3-50");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::BadValue { .. })
        ));
    }

    #[test]
    fn sim_loss_model_selection() {
        let mut s = SimConfig::default();
        s.loss = 0.1;
        assert_eq!(
            s.loss_model(),
            crate::sim::link::LossModel::Bernoulli { p: 0.1 }
        );
        s.burst = Some(BurstParams::default());
        assert!(matches!(
            s.loss_model(),
            crate::sim::link::LossModel::GilbertElliott { .. }
        ));
        assert_eq!(
            SimConfig::ideal().loss_model(),
            crate::sim::link::LossModel::Perfect
        );
    }

    #[test]
    fn quant_policy_mapping() {
        let q = QuantConfig {
            bits: 3,
            adaptive: false,
            max_bits: 16,
        };
        assert_eq!(q.policy(), crate::quant::BitPolicy::Fixed(3));
        let qa = QuantConfig {
            adaptive: true,
            ..q
        };
        assert_eq!(
            qa.policy(),
            crate::quant::BitPolicy::Adaptive {
                min_bits: 3,
                max_bits: 16
            }
        );
    }
}
