//! Experiment configuration.
//!
//! Two layers:
//! * typed configs consumed by the engines ([`GadmmConfig`], [`PsConfig`],
//!   [`QuantConfig`], [`NetConfig`]) with paper-faithful defaults;
//! * a minimal `key = value` config-file format ([`KvMap`], a TOML subset:
//!   comments with `#`, bare sections ignored) so runs are scriptable
//!   without `serde`. CLI flags override file values (see `cli`).

use crate::net::channel::ChannelParams;
use crate::quant::BitPolicy;
use std::collections::BTreeMap;

/// Stochastic-quantizer configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantConfig {
    /// Fixed bit-width `b` (paper: 2 for linreg, 8 for the DNN task).
    pub bits: u8,
    /// Use the adaptive eq. (11) rule instead of a fixed width.
    pub adaptive: bool,
    /// Cap for the adaptive rule.
    pub max_bits: u8,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            bits: 2,
            adaptive: false,
            max_bits: 16,
        }
    }
}

impl QuantConfig {
    pub fn policy(&self) -> BitPolicy {
        if self.adaptive {
            BitPolicy::Adaptive {
                min_bits: self.bits,
                max_bits: self.max_bits,
            }
        } else {
            BitPolicy::Fixed(self.bits)
        }
    }
}

/// GADMM-family engine configuration.
#[derive(Clone, Debug)]
pub struct GadmmConfig {
    /// Number of workers N (paper: 50 linreg, 10 DNN).
    pub workers: usize,
    /// Disagreement penalty ρ (paper: 24 linreg, 20 DNN).
    pub rho: f32,
    /// Dual damping α: 1.0 for convex Q-GADMM (eq. (18)); 0.01 for
    /// Q-SGADMM (Sec. V-B).
    pub dual_step: f32,
    /// `Some` ⇒ quantized variant (Q-GADMM / Q-SGADMM); `None` ⇒ full
    /// precision (GADMM / SGADMM).
    pub quant: Option<QuantConfig>,
}

impl Default for GadmmConfig {
    fn default() -> Self {
        GadmmConfig {
            workers: 50,
            rho: 24.0,
            dual_step: 1.0,
            quant: Some(QuantConfig::default()),
        }
    }
}

/// Parameter-server baseline configuration (GD/QGD/SGD/QSGD/ADIANA).
#[derive(Clone, Debug)]
pub struct PsConfig {
    pub workers: usize,
    /// Step size. `None` ⇒ auto-tune to 1/L from the data (GD-family).
    pub lr: Option<f64>,
    /// Quantize uplinks (QGD/QSGD/ADIANA).
    pub quant: Option<QuantConfig>,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig {
            workers: 50,
            lr: None,
            quant: None,
        }
    }
}

/// Wireless testbed configuration.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Deployment square side (m). Paper: 250.
    pub area_side: f64,
    pub channel: ChannelParams,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            area_side: 250.0,
            channel: ChannelParams::default(),
        }
    }
}

/// Top-level experiment description used by the CLI and figure harness.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub gadmm: GadmmConfig,
    pub net: NetConfig,
    /// Max iterations per run.
    pub iterations: u64,
    /// Loss-gap target (linreg figures).
    pub loss_target: f64,
    /// Accuracy target (DNN figures).
    pub accuracy_target: f64,
    /// Number of random drops for the CDF figures.
    pub drops: usize,
    /// Base seed.
    pub seed: u64,
    /// Output directory for reports.
    pub results_dir: String,
    /// Execute local solves through the PJRT artifacts instead of the
    /// native backend (requires `make artifacts`).
    pub use_xla: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            gadmm: GadmmConfig::default(),
            net: NetConfig::default(),
            iterations: 2_000,
            loss_target: 1e-4,
            accuracy_target: 0.90,
            drops: 20,
            seed: 1,
            results_dir: "results".to_string(),
            use_xla: false,
        }
    }
}

impl ExperimentConfig {
    /// Apply `key = value` overrides (from file or CLI).
    pub fn apply_kv(&mut self, kv: &KvMap) -> Result<(), ConfigError> {
        for (k, v) in kv.iter() {
            self.apply_one(k, v)?;
        }
        Ok(())
    }

    fn apply_one(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        let bad = |why: &str| ConfigError::BadValue {
            key: key.to_string(),
            value: value.to_string(),
            why: why.to_string(),
        };
        match key {
            "workers" => self.gadmm.workers = value.parse().map_err(|_| bad("usize"))?,
            "rho" => self.gadmm.rho = value.parse().map_err(|_| bad("f32"))?,
            "dual_step" | "dual-step" | "alpha" => {
                self.gadmm.dual_step = value.parse().map_err(|_| bad("f32"))?
            }
            "bits" => {
                let bits: u8 = value.parse().map_err(|_| bad("u8"))?;
                if bits == 0 {
                    self.gadmm.quant = None; // bits=0 means full precision
                } else {
                    let mut q = self.gadmm.quant.unwrap_or_default();
                    q.bits = bits;
                    self.gadmm.quant = Some(q);
                }
            }
            "adaptive_bits" | "adaptive-bits" => {
                let mut q = self.gadmm.quant.unwrap_or_default();
                q.adaptive = value.parse().map_err(|_| bad("bool"))?;
                self.gadmm.quant = Some(q);
            }
            "iterations" | "iters" => {
                self.iterations = value.parse().map_err(|_| bad("u64"))?
            }
            "loss_target" | "loss-target" => self.loss_target = value.parse().map_err(|_| bad("f64"))?,
            "accuracy_target" | "accuracy-target" => {
                self.accuracy_target = value.parse().map_err(|_| bad("f64"))?
            }
            "drops" => self.drops = value.parse().map_err(|_| bad("usize"))?,
            "seed" => self.seed = value.parse().map_err(|_| bad("u64"))?,
            "results_dir" | "results-dir" | "out" => self.results_dir = value.to_string(),
            "use_xla" | "use-xla" => self.use_xla = value.parse().map_err(|_| bad("bool"))?,
            "bandwidth_mhz" | "bandwidth-mhz" => {
                self.net.channel.total_bandwidth_hz =
                    value.parse::<f64>().map_err(|_| bad("f64"))? * 1e6
            }
            "slot_ms" | "slot-ms" => {
                self.net.channel.slot_secs =
                    value.parse::<f64>().map_err(|_| bad("f64"))? * 1e-3
            }
            "area_side" | "area-side" => self.net.area_side = value.parse().map_err(|_| bad("f64"))?,
            _ => {
                return Err(ConfigError::UnknownKey {
                    key: key.to_string(),
                })
            }
        }
        Ok(())
    }
}

/// Ordered string→string map parsed from `key = value` lines.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KvMap {
    entries: BTreeMap<String, String>,
}

impl KvMap {
    pub fn new() -> KvMap {
        KvMap::default()
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.entries.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Parse config text: `key = value` per line, `#` comments, blank lines
    /// and `[section]` headers ignored (sections exist for human grouping).
    pub fn parse(text: &str) -> Result<KvMap, ConfigError> {
        let mut map = KvMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || (line.starts_with('[') && line.ends_with(']')) {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(ConfigError::Syntax {
                    line: lineno + 1,
                    text: raw.to_string(),
                });
            };
            let key = k.trim();
            let val = v.trim().trim_matches('"');
            if key.is_empty() {
                return Err(ConfigError::Syntax {
                    line: lineno + 1,
                    text: raw.to_string(),
                });
            }
            map.set(key, val);
        }
        Ok(map)
    }
}

/// Configuration errors.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("config syntax error on line {line}: {text:?}")]
    Syntax { line: usize, text: String },
    #[error("unknown config key {key:?}")]
    UnknownKey { key: String },
    #[error("bad value for {key:?}: {value:?} (expected {why})")]
    BadValue {
        key: String,
        value: String,
        why: String,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kv_file() {
        let text = r#"
            # experiment
            [run]
            workers = 10
            rho = 12.5
            bits = 2
            results_dir = "out/run1"
        "#;
        let kv = KvMap::parse(text).unwrap();
        assert_eq!(kv.get("workers"), Some("10"));
        assert_eq!(kv.get("results_dir"), Some("out/run1"));

        let mut cfg = ExperimentConfig::default();
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.gadmm.workers, 10);
        assert_eq!(cfg.gadmm.rho, 12.5);
        assert_eq!(cfg.gadmm.quant.unwrap().bits, 2);
        assert_eq!(cfg.results_dir, "out/run1");
    }

    #[test]
    fn bits_zero_disables_quantization() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("bits", "0");
        cfg.apply_kv(&kv).unwrap();
        assert!(cfg.gadmm.quant.is_none());
    }

    #[test]
    fn rejects_unknown_key_and_bad_value() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("wurkers", "10");
        assert!(matches!(
            cfg.apply_kv(&kv),
            Err(ConfigError::UnknownKey { .. })
        ));
        let mut kv2 = KvMap::new();
        kv2.set("workers", "ten");
        assert!(matches!(
            cfg.apply_kv(&kv2),
            Err(ConfigError::BadValue { .. })
        ));
    }

    #[test]
    fn rejects_syntax_errors() {
        assert!(KvMap::parse("just words\n").is_err());
        assert!(KvMap::parse(" = novalue\n").is_err());
        assert!(KvMap::parse("# fine\n[ok]\na = 1\n").is_ok());
    }

    #[test]
    fn bandwidth_in_mhz() {
        let mut cfg = ExperimentConfig::default();
        let mut kv = KvMap::new();
        kv.set("bandwidth_mhz", "40");
        kv.set("slot_ms", "100");
        cfg.apply_kv(&kv).unwrap();
        assert_eq!(cfg.net.channel.total_bandwidth_hz, 40e6);
        assert_eq!(cfg.net.channel.slot_secs, 0.1);
    }

    #[test]
    fn quant_policy_mapping() {
        let q = QuantConfig {
            bits: 3,
            adaptive: false,
            max_bits: 16,
        };
        assert_eq!(q.policy(), crate::quant::BitPolicy::Fixed(3));
        let qa = QuantConfig {
            adaptive: true,
            ..q
        };
        assert_eq!(
            qa.policy(),
            crate::quant::BitPolicy::Adaptive {
                min_bits: 3,
                max_bits: 16
            }
        );
    }
}
