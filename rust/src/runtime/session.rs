//! The unified Session API: **one builder, one runtime trait, one report**.
//!
//! The paper's variants differ along orthogonal axes — local problem,
//! compressor, topology, and execution substrate — and before this module
//! the *run* axis was three parallel worlds (`GadmmEngine::run`,
//! `run_threaded`, `SimulatedGadmm::run`), each with its own report type
//! and hand-assembled metric closure. A [`Session`] resolves all four
//! axes from one configuration:
//!
//! ```no_run
//! use qgadmm::runtime::session::{DriverKind, ProblemKind, Session};
//!
//! let summary = Session::new(ProblemKind::LogReg)
//!     .workers(8)
//!     .driver(DriverKind::Sim)
//!     .iterations(200)
//!     .run()
//!     .unwrap();
//! println!("accuracy {:.3} after {} bits", summary.final_value(), summary.comm.bits);
//! ```
//!
//! * [`ProblemKind`] is the open problem registry: `linreg` (the paper's
//!   convex task), `diag-linreg` (the d = 10k scale task), `mlp` (the
//!   Sec. V-B DNN), and `logreg` (binary classification — the proof the
//!   registry accepts new members without touching any runtime).
//! * [`DriverKind`] selects the substrate; every driver implements the
//!   [`Driver`] trait, honors every [`RunOptions`] field (including early
//!   stopping on the threaded runtime), and returns the same
//!   [`RunSummary`].
//! * [`Observer`] streams `on_eval` / `on_broadcast` events out of the
//!   run, replacing the ad-hoc metric closures.
//!
//! Bit-exactness: for identity-ordered topologies (everything
//! [`TopologyKind::build`] produces), the three drivers are bit-for-bit
//! equivalent through this API — pinned by `tests/session_equivalence.rs`.

use anyhow::Context as _;

use crate::config::{Dropout, ExperimentConfig, GadmmConfig, SimConfig, TcpConfig};
use crate::coordinator::engine::{GadmmEngine, InvalidRunOptions, RunOptions};
use crate::coordinator::simulated::SimulatedGadmm;
use crate::coordinator::threaded::run_threaded_on;
use crate::data::images::{ImageDataset, ImageSpec};
use crate::data::linreg::{LinRegDataset, LinRegSpec};
use crate::data::partition::Partition;
use crate::figures::helpers::{DNN_ALPHA, DNN_BITS, DNN_RHO, LINREG_RHO};
use crate::metrics::recorder::CurvePoint;
use crate::metrics::report::RunSummary;
use crate::metrics::{BroadcastEvent, NoopObserver, Observer};
use crate::telemetry::export::{write_chrome_trace, write_jsonl};
use crate::telemetry::{Record, TelemetryOptions};
use crate::model::linreg::LinRegProblem;
use crate::model::logreg::{LogRegProblem, LogRegSpec};
use crate::model::mlp::{MlpDims, MlpProblem};
use crate::model::scale::DiagLinRegProblem;
use crate::coordinator::residuals::RhoPolicy;
use crate::model::{BlockLayout, LocalProblem, NeighborCtx, WorkerSolver};
use crate::net::geometry::{collinear, Point};
use crate::net::hier::{HierLayout, HierTopology};
use crate::net::tcp::run_tcp_on;
use crate::net::topology::{Topology, TopologyKind};

/// Default disagreement penalty for the `logreg` task (its per-worker
/// logistic Hessian scale is ≈ 0.25·shard size ≈ 100 at the default
/// sharding; ρ of the same order keeps consensus and fit balanced).
pub const LOGREG_RHO: f32 = 50.0;

/// The valid `--problem` spellings, cited by parse errors.
pub const PROBLEM_KINDS: &str = "linreg, diag-linreg, mlp, logreg";
/// The valid `--driver` spellings, cited by parse errors.
pub const DRIVER_KINDS: &str = "engine, threaded, sim, tcp";

/// The problem registry: which local problem (and figure of merit) a
/// session trains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    /// The paper's convex least-squares task (loss-gap metric).
    LinReg,
    /// Diagonal-Gram linreg at d = 10k (`model::scale`; loss-gap metric).
    DiagLinReg,
    /// The Sec. V-B MLP image task (accuracy metric, Q-SGADMM solves).
    Mlp,
    /// Binary logistic regression (accuracy metric, deterministic Newton
    /// solves) — the registry's proof of openness.
    LogReg,
}

impl ProblemKind {
    /// Parse a CLI/config name. Unknown names are typed errors citing the
    /// valid set, never a silent default.
    pub fn parse(text: &str) -> Result<ProblemKind, String> {
        match text.trim().to_ascii_lowercase().as_str() {
            "linreg" | "linear-regression" | "linear_regression" => Ok(ProblemKind::LinReg),
            "diag-linreg" | "diag_linreg" | "diag" | "scale" => Ok(ProblemKind::DiagLinReg),
            "mlp" | "dnn" => Ok(ProblemKind::Mlp),
            "logreg" | "logistic" | "logistic-regression" => Ok(ProblemKind::LogReg),
            other => Err(format!(
                "unknown problem {other:?}; valid problems: {PROBLEM_KINDS}"
            )),
        }
    }

    /// Name as spelled on the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            ProblemKind::LinReg => "linreg",
            ProblemKind::DiagLinReg => "diag-linreg",
            ProblemKind::Mlp => "mlp",
            ProblemKind::LogReg => "logreg",
        }
    }
}

/// Which execution substrate a session runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriverKind {
    /// The deterministic in-process engine (with the parallel phase
    /// executor behind `GadmmConfig::threads`).
    Engine,
    /// One OS thread per worker over in-process mailboxes.
    Threaded,
    /// The discrete-event network simulator.
    Sim,
    /// Real TCP sockets speaking the versioned wire format — a local
    /// loopback cluster by default, or one worker of a multi-process
    /// deployment via `TcpConfig::listen`/`peers`.
    Tcp,
}

impl DriverKind {
    /// Parse a CLI/config name with a typed error citing the valid set.
    pub fn parse(text: &str) -> Result<DriverKind, String> {
        match text.trim().to_ascii_lowercase().as_str() {
            "engine" | "deterministic" => Ok(DriverKind::Engine),
            "threaded" | "threads" | "distributed" => Ok(DriverKind::Threaded),
            "sim" | "simulated" | "simulator" => Ok(DriverKind::Sim),
            "tcp" | "sockets" => Ok(DriverKind::Tcp),
            other => Err(format!(
                "unknown driver {other:?}; valid drivers: {DRIVER_KINDS}"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DriverKind::Engine => "engine",
            DriverKind::Threaded => "threaded",
            DriverKind::Sim => "sim",
            DriverKind::Tcp => "tcp",
        }
    }
}

/// Whether a problem's figure of merit is loss-style (early stop on
/// `stop_below`) or accuracy-style (early stop on `stop_above`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    LossGap,
    Accuracy,
}

/// A problem the Session registry can hand to any [`Driver`]: the fleet
/// [`LocalProblem`] plus the figure of merit and the per-worker split the
/// threaded driver needs. Implement this (and register a
/// [`ProblemKind`]) to open a new workload to all three runtimes at once.
pub trait SessionProblem: LocalProblem + Send {
    /// Problem name as spelled on the CLI.
    fn name(&self) -> &'static str;

    /// Loss-gap or accuracy metric (selects the early-stop direction and
    /// which `evaluate` inputs are read).
    fn metric_kind(&self) -> MetricKind;

    /// The figure of merit. Loss-gap problems read `objective_sum`
    /// (`Σ_p f_p(θ_p)` accumulated in ascending position order — the
    /// engine-wide bit-exactness convention); accuracy problems read
    /// `thetas` (position-indexed models). Drivers supply whichever
    /// [`Self::metric_kind`] demands; the other argument may be empty.
    fn evaluate(&self, objective_sum: f64, thetas: &[Vec<f32>]) -> f64;

    /// Shared initial model, when the problem requires seed-shared init
    /// (the MLP's He-normal init; `None` starts every worker at zero).
    fn initial_theta(&self) -> Option<Vec<f32>>;

    /// Give up the per-worker solvers (the threaded driver ships them to
    /// worker threads). The remaining `self` stays usable as the metric
    /// evaluator only — `solve`/`objective` may panic afterwards.
    fn take_workers(&mut self) -> Vec<Box<dyn WorkerSolver>>;
}

impl LocalProblem for Box<dyn SessionProblem> {
    fn dims(&self) -> usize {
        (**self).dims()
    }

    fn workers(&self) -> usize {
        (**self).workers()
    }

    fn solve(&mut self, worker: usize, ctx: &NeighborCtx<'_>, out: &mut [f32]) {
        (**self).solve(worker, ctx, out)
    }

    fn objective(&self, worker: usize, theta: &[f32]) -> f64 {
        (**self).objective(worker, theta)
    }

    fn block_layout(&self) -> BlockLayout {
        (**self).block_layout()
    }

    fn split_workers(&mut self) -> Option<Vec<&mut dyn WorkerSolver>> {
        (**self).split_workers()
    }
}

// ---------------------------------------------------------------------
// Registry entries: thin wrappers binding each problem to its metric.
// ---------------------------------------------------------------------

/// Forward every [`LocalProblem`] method to the wrapper's inner
/// `problem` field — one definition shared by all registry entries, so a
/// future trait method cannot be missed on a subset of them.
macro_rules! forward_local_problem {
    ($ty:ty) => {
        impl LocalProblem for $ty {
            fn dims(&self) -> usize {
                self.problem.dims()
            }
            fn workers(&self) -> usize {
                self.problem.workers()
            }
            fn solve(&mut self, worker: usize, ctx: &NeighborCtx<'_>, out: &mut [f32]) {
                self.problem.solve(worker, ctx, out)
            }
            fn objective(&self, worker: usize, theta: &[f32]) -> f64 {
                self.problem.objective(worker, theta)
            }
            fn block_layout(&self) -> BlockLayout {
                self.problem.block_layout()
            }
            fn split_workers(&mut self) -> Option<Vec<&mut dyn WorkerSolver>> {
                self.problem.split_workers()
            }
        }
    };
}

/// Box a concrete per-worker solver list for the threaded runtime.
fn box_workers<W: WorkerSolver + 'static>(workers: Vec<W>) -> Vec<Box<dyn WorkerSolver>> {
    workers
        .into_iter()
        .map(|w| Box::new(w) as Box<dyn WorkerSolver>)
        .collect()
}

/// `linreg`: loss gap `|Σ f_n(θ_n) − F*|` against the closed-form optimum.
struct LinRegSession {
    problem: LinRegProblem,
    f_star: f64,
}

forward_local_problem!(LinRegSession);

impl SessionProblem for LinRegSession {
    fn name(&self) -> &'static str {
        "linreg"
    }
    fn metric_kind(&self) -> MetricKind {
        MetricKind::LossGap
    }
    fn evaluate(&self, objective_sum: f64, _thetas: &[Vec<f32>]) -> f64 {
        (objective_sum - self.f_star).abs()
    }
    fn initial_theta(&self) -> Option<Vec<f32>> {
        None
    }
    fn take_workers(&mut self) -> Vec<Box<dyn WorkerSolver>> {
        box_workers(self.problem.take_workers())
    }
}

/// `diag-linreg`: the scale task's loss gap against its closed form.
struct DiagLinRegSession {
    problem: DiagLinRegProblem,
    f_star: f64,
}

forward_local_problem!(DiagLinRegSession);

impl SessionProblem for DiagLinRegSession {
    fn name(&self) -> &'static str {
        "diag-linreg"
    }
    fn metric_kind(&self) -> MetricKind {
        MetricKind::LossGap
    }
    fn evaluate(&self, objective_sum: f64, _thetas: &[Vec<f32>]) -> f64 {
        (objective_sum - self.f_star).abs()
    }
    fn initial_theta(&self) -> Option<Vec<f32>> {
        None
    }
    fn take_workers(&mut self) -> Vec<Box<dyn WorkerSolver>> {
        box_workers(self.problem.take_workers())
    }
}

/// `mlp`: test accuracy of the worker-averaged model, seed-shared init.
struct MlpSession {
    problem: MlpProblem,
    init: Vec<f32>,
}

forward_local_problem!(MlpSession);

impl SessionProblem for MlpSession {
    fn name(&self) -> &'static str {
        "mlp"
    }
    fn metric_kind(&self) -> MetricKind {
        MetricKind::Accuracy
    }
    fn evaluate(&self, _objective_sum: f64, thetas: &[Vec<f32>]) -> f64 {
        self.problem.average_model_accuracy(thetas)
    }
    fn initial_theta(&self) -> Option<Vec<f32>> {
        Some(self.init.clone())
    }
    fn take_workers(&mut self) -> Vec<Box<dyn WorkerSolver>> {
        box_workers(self.problem.take_workers())
    }
}

/// `logreg`: held-out accuracy of the worker-averaged model.
struct LogRegSession {
    problem: LogRegProblem,
}

forward_local_problem!(LogRegSession);

impl SessionProblem for LogRegSession {
    fn name(&self) -> &'static str {
        "logreg"
    }
    fn metric_kind(&self) -> MetricKind {
        MetricKind::Accuracy
    }
    fn evaluate(&self, _objective_sum: f64, thetas: &[Vec<f32>]) -> f64 {
        self.problem.average_model_accuracy(thetas)
    }
    fn initial_theta(&self) -> Option<Vec<f32>> {
        None
    }
    fn take_workers(&mut self) -> Vec<Box<dyn WorkerSolver>> {
        box_workers(self.problem.take_workers())
    }
}

// ---------------------------------------------------------------------
// Drivers
// ---------------------------------------------------------------------

/// One execution substrate behind the Session facade. Every
/// implementation honors every [`RunOptions`] field and returns the same
/// [`RunSummary`].
pub trait Driver {
    /// Which substrate this is.
    fn kind(&self) -> DriverKind;

    /// Run to completion (or early stop) under `opts`, streaming progress
    /// into `observer`.
    fn run(
        &mut self,
        opts: &RunOptions,
        observer: &mut dyn Observer,
    ) -> anyhow::Result<RunSummary>;
}

/// Position-ordered objective sum — the canonical loss-gap metric input
/// (bit-identical across all three drivers).
fn engine_metric(eng: &GadmmEngine<Box<dyn SessionProblem>>) -> f64 {
    match eng.problem().metric_kind() {
        MetricKind::LossGap => {
            let sum: f64 = (0..eng.workers()).map(|p| eng.local_objective_at(p)).sum();
            eng.problem().evaluate(sum, &[])
        }
        MetricKind::Accuracy => {
            let thetas: Vec<Vec<f32>> =
                (0..eng.workers()).map(|p| eng.theta_at(p).to_vec()).collect();
            eng.problem().evaluate(0.0, &thetas)
        }
    }
}

/// The deterministic engine behind the [`Driver`] trait.
pub struct EngineDriver {
    engine: GadmmEngine<Box<dyn SessionProblem>>,
}

impl EngineDriver {
    pub fn new(
        cfg: GadmmConfig,
        problem: Box<dyn SessionProblem>,
        topo: Topology,
        seed: u64,
    ) -> EngineDriver {
        let mut engine = GadmmEngine::new(cfg, problem, topo, seed);
        let init = engine.problem().initial_theta();
        if let Some(init) = init {
            engine.set_initial_theta(&init);
        }
        EngineDriver { engine }
    }

    /// The wrapped engine (for energy contexts and other engine-only
    /// extras).
    pub fn engine_mut(&mut self) -> &mut GadmmEngine<Box<dyn SessionProblem>> {
        &mut self.engine
    }
}

impl Driver for EngineDriver {
    fn kind(&self) -> DriverKind {
        DriverKind::Engine
    }

    fn run(
        &mut self,
        opts: &RunOptions,
        observer: &mut dyn Observer,
    ) -> anyhow::Result<RunSummary> {
        opts.validate()?;
        Ok(self.engine.run_observed(opts, engine_metric, observer))
    }
}

/// The one-thread-per-worker runtime behind the [`Driver`] trait. Its
/// solvers move onto the worker threads, so it runs exactly once.
pub struct ThreadedDriver {
    cfg: GadmmConfig,
    topo: Topology,
    seed: u64,
    problem: Option<Box<dyn SessionProblem>>,
}

impl Driver for ThreadedDriver {
    fn kind(&self) -> DriverKind {
        DriverKind::Threaded
    }

    fn run(
        &mut self,
        opts: &RunOptions,
        observer: &mut dyn Observer,
    ) -> anyhow::Result<RunSummary> {
        opts.validate()?;
        let mut problem = self.problem.take().ok_or_else(|| {
            anyhow::anyhow!(
                "a threaded session can only run once: its solvers moved onto the \
                 worker threads on the first run"
            )
        })?;
        let init = problem.initial_theta();
        let solvers = problem.take_workers();
        // Accuracy metrics never read the objective sum — spare the
        // workers the per-eval f_n(θ) pass.
        let needs_objective = problem.metric_kind() == MetricKind::LossGap;
        let evaluator = problem;
        run_threaded_on(
            &self.topo,
            &self.cfg,
            solvers,
            opts,
            self.seed,
            init.as_deref(),
            needs_objective,
            move |objective_sum, thetas| evaluator.evaluate(objective_sum, thetas),
            observer,
        )
    }
}

/// The discrete-event simulator behind the [`Driver`] trait.
pub struct SimDriver {
    sim: SimulatedGadmm<Box<dyn SessionProblem>>,
}

impl SimDriver {
    pub fn new(
        cfg: GadmmConfig,
        sim_cfg: SimConfig,
        problem: Box<dyn SessionProblem>,
        topo: Topology,
        points: Vec<crate::net::geometry::Point>,
        seed: u64,
    ) -> SimDriver {
        let mut sim = SimulatedGadmm::new(cfg, sim_cfg, problem, topo, points, seed);
        let init = sim.problem().initial_theta();
        if let Some(init) = init {
            sim.set_initial_theta(&init);
        }
        SimDriver { sim }
    }

    /// Install the grouped layout of a `hier:` topology: the event queue
    /// shards per group and dropouts re-stitch group-locally with leader
    /// re-election instead of collapsing to one global chain.
    pub fn install_hier(&mut self, layout: HierLayout) {
        self.sim.set_hier_layout(layout);
    }
}

impl Driver for SimDriver {
    fn kind(&self) -> DriverKind {
        DriverKind::Sim
    }

    fn run(
        &mut self,
        opts: &RunOptions,
        observer: &mut dyn Observer,
    ) -> anyhow::Result<RunSummary> {
        opts.validate()?;
        Ok(self.sim.run_observed(
            opts,
            |s| match s.problem().metric_kind() {
                MetricKind::LossGap => s.problem().evaluate(s.global_objective(), &[]),
                MetricKind::Accuracy => {
                    let thetas: Vec<Vec<f32>> = s
                        .chain()
                        .iter()
                        .map(|&w| s.theta_of(w).to_vec())
                        .collect();
                    s.problem().evaluate(0.0, &thetas)
                }
            },
            observer,
        ))
    }
}

/// The real-socket runtime behind the [`Driver`] trait: a loopback TCP
/// cluster by default, or one worker of a multi-process deployment when
/// `TcpConfig::listen` is set. Like [`ThreadedDriver`], its solvers move
/// onto the worker threads, so it runs exactly once.
pub struct TcpDriver {
    cfg: GadmmConfig,
    topo: Topology,
    seed: u64,
    tcp: TcpConfig,
    dropouts: Vec<Dropout>,
    points: Vec<Point>,
    problem: Option<Box<dyn SessionProblem>>,
}

impl Driver for TcpDriver {
    fn kind(&self) -> DriverKind {
        DriverKind::Tcp
    }

    fn run(
        &mut self,
        opts: &RunOptions,
        observer: &mut dyn Observer,
    ) -> anyhow::Result<RunSummary> {
        opts.validate()?;
        let mut problem = self.problem.take().ok_or_else(|| {
            anyhow::anyhow!(
                "a tcp session can only run once: its solvers moved onto the \
                 worker threads on the first run"
            )
        })?;
        let init = problem.initial_theta();
        let solvers = problem.take_workers();
        let needs_objective = problem.metric_kind() == MetricKind::LossGap;
        let evaluator = problem;
        run_tcp_on(
            &self.topo,
            &self.cfg,
            &self.tcp,
            &self.dropouts,
            self.points.clone(),
            solvers,
            opts,
            self.seed,
            init.as_deref(),
            needs_objective,
            move |objective_sum, thetas| evaluator.evaluate(objective_sum, thetas),
            observer,
        )
    }
}

// ---------------------------------------------------------------------
// The Session builder
// ---------------------------------------------------------------------

/// A fully-specified run: problem × compressor × topology × driver, plus
/// [`RunOptions`]. Construct with [`Session::new`] /
/// [`Session::from_config`], refine with the builder methods, then
/// [`Session::run`] (or [`Session::into_driver`] to drive manually).
#[derive(Clone, Debug)]
pub struct Session {
    cfg: ExperimentConfig,
    quick: bool,
    opts_override: Option<RunOptions>,
    telemetry: TelemetryOptions,
}

/// The session's trace collector: forwards every [`Observer`] callback to
/// the user's observer while gathering the structured telemetry stream
/// for the exporters configured via [`Session::telemetry`]. Its
/// `wants_telemetry` is unconditionally `true` — it exists to collect —
/// while broadcast interest passes through to the inner observer.
struct TelemetryTee<'a> {
    inner: &'a mut dyn Observer,
    records: Vec<Record>,
}

impl Observer for TelemetryTee<'_> {
    fn on_eval(&mut self, point: &CurvePoint) {
        self.inner.on_eval(point);
    }

    fn on_broadcast(&mut self, event: &BroadcastEvent) {
        self.inner.on_broadcast(event);
    }

    fn wants_broadcasts(&self) -> bool {
        self.inner.wants_broadcasts()
    }

    fn on_record(&mut self, record: &Record) {
        self.records.push(record.clone());
        self.inner.on_record(record);
    }

    fn wants_telemetry(&self) -> bool {
        true
    }
}

/// A session resolved against its problem's defaults — the exact
/// hyperparameters and options a run will use.
struct Resolved {
    problem: ProblemKind,
    driver: DriverKind,
    topology: TopologyKind,
    gadmm: GadmmConfig,
    sim: SimConfig,
    tcp: TcpConfig,
    opts: RunOptions,
    seed: u64,
    scale_dims: usize,
    quick: bool,
}

impl Session {
    /// A session for `problem` with every other axis at its default
    /// (engine driver, line topology, stochastic 2-bit compressor).
    pub fn new(problem: ProblemKind) -> Session {
        Session::from_config(&ExperimentConfig::default()).problem(problem)
    }

    /// Build from a full experiment configuration (the CLI path: every
    /// `run` invocation goes through here). Per-problem re-defaulting —
    /// the substitutions the old `train-*` subcommands hard-coded — is
    /// applied at run time, so un-overridden defaults (ρ = 24, 50
    /// workers, 2 bits) resolve to each task's tuned values while
    /// explicit settings always win.
    pub fn from_config(cfg: &ExperimentConfig) -> Session {
        let mut telemetry = TelemetryOptions::off();
        if let Some(path) = &cfg.trace_jsonl {
            telemetry = telemetry.with_jsonl(path);
        }
        if let Some(path) = &cfg.chrome_trace {
            telemetry = telemetry.with_chrome(path);
        }
        Session {
            cfg: cfg.clone(),
            quick: false,
            opts_override: None,
            telemetry,
        }
    }

    pub fn problem(mut self, kind: ProblemKind) -> Session {
        self.cfg.problem = kind;
        self
    }

    pub fn driver(mut self, kind: DriverKind) -> Session {
        self.cfg.driver = kind;
        self
    }

    pub fn topology(mut self, kind: TopologyKind) -> Session {
        self.cfg.topology = kind;
        self
    }

    pub fn workers(mut self, n: usize) -> Session {
        self.cfg.gadmm.workers = n;
        self
    }

    pub fn compressor(mut self, comp: crate::config::CompressorConfig) -> Session {
        self.cfg.gadmm.compressor = comp;
        self
    }

    pub fn rho(mut self, rho: f32) -> Session {
        self.cfg.gadmm.rho = rho;
        self
    }

    /// How ρ evolves across iterations (fixed, or residual-balance
    /// adaptive); honored identically by all three drivers.
    pub fn rho_policy(mut self, policy: RhoPolicy) -> Session {
        self.cfg.rho_policy = policy;
        self
    }

    pub fn threads(mut self, threads: usize) -> Session {
        self.cfg.gadmm.threads = threads;
        self
    }

    pub fn seed(mut self, seed: u64) -> Session {
        self.cfg.seed = seed;
        self
    }

    pub fn iterations(mut self, iterations: u64) -> Session {
        self.cfg.iterations = iterations;
        self
    }

    pub fn eval_every(mut self, eval_every: u64) -> Session {
        self.cfg.eval_every = Some(eval_every);
        self
    }

    pub fn loss_target(mut self, target: f64) -> Session {
        self.cfg.loss_target = target;
        self
    }

    pub fn accuracy_target(mut self, target: f64) -> Session {
        self.cfg.accuracy_target = target;
        self
    }

    pub fn sim_config(mut self, sim: SimConfig) -> Session {
        self.cfg.sim = sim;
        self
    }

    /// Socket endpoints, timeout, and fault-detection mode for the tcp
    /// driver (ignored by the in-process drivers).
    pub fn tcp_config(mut self, tcp: TcpConfig) -> Session {
        self.cfg.tcp = tcp;
        self
    }

    pub fn scale_dims(mut self, dims: usize) -> Session {
        self.cfg.scale_dims = dims;
        self
    }

    /// Reduced-scale datasets (CI/tests): smaller synthetic corpora, same
    /// code paths.
    pub fn quick(mut self, quick: bool) -> Session {
        self.quick = quick;
        self
    }

    /// Take full control of the run loop options (iterations, eval
    /// cadence, both stop thresholds) instead of the problem's defaults.
    pub fn options(mut self, opts: RunOptions) -> Session {
        self.opts_override = Some(opts);
        self
    }

    /// Attach structured-trace exporters to the run: the driver streams
    /// telemetry records through a collecting tee observer and the
    /// session writes the configured outputs (JSONL and/or Chrome
    /// trace-event JSON — load the latter in `chrome://tracing` or
    /// Perfetto) after the run completes. With the `telemetry` cargo
    /// feature disabled the exporters still write, but carry no records.
    pub fn telemetry(mut self, opts: TelemetryOptions) -> Session {
        self.telemetry = opts;
        self
    }

    pub fn problem_kind(&self) -> ProblemKind {
        self.cfg.problem
    }

    pub fn driver_kind(&self) -> DriverKind {
        self.cfg.driver
    }

    /// The run options this session will use after per-problem
    /// resolution (the builder override, when set).
    pub fn resolved_options(&self) -> RunOptions {
        self.resolve().opts
    }

    /// The engine configuration after per-problem resolution — the one
    /// shared source of the re-defaulting rules, also consumed by run
    /// paths that cannot go through a [`Driver`] (the CLI's XLA branch).
    pub fn resolved_gadmm(&self) -> GadmmConfig {
        self.resolve().gadmm
    }

    /// One-line description for CLI headers.
    pub fn describe(&self) -> String {
        let r = self.resolve();
        format!(
            "problem={} driver={} topology={} workers={} rho={} compressor={} iters={} eval_every={}",
            r.problem.name(),
            r.driver.name(),
            r.topology.name(),
            r.gadmm.workers,
            r.gadmm.rho,
            r.gadmm.compressor.name(),
            r.opts.iterations,
            r.opts.eval_every,
        )
    }

    /// Apply the per-problem re-defaults (the old `train-*` logic): a
    /// still-default worker count / ρ / quantizer width resolves to the
    /// task's tuned value; anything explicitly set passes through.
    fn resolve(&self) -> Resolved {
        let cfg = &self.cfg;
        let mut gadmm = cfg.gadmm.clone();
        let eval_default;
        let mut iterations = cfg.iterations;
        let mut stop_below = None;
        let mut stop_above = None;
        match cfg.problem {
            ProblemKind::LinReg => {
                if gadmm.rho == 24.0 {
                    // The paper's ρ = 24 was tuned to California Housing
                    // units; the synthetic default needs the fig7 value.
                    gadmm.rho = LINREG_RHO;
                }
                eval_default = 1;
                stop_below = Some(cfg.loss_target);
            }
            ProblemKind::DiagLinReg => {
                if gadmm.workers == 50 {
                    gadmm.workers = 16;
                }
                if gadmm.rho == 24.0 {
                    // Whitened scale problem: curvatures in [0.5, 8].
                    gadmm.rho = 4.0;
                }
                eval_default = 10;
                stop_below = Some(cfg.loss_target);
            }
            ProblemKind::Mlp => {
                if gadmm.workers == 50 {
                    gadmm.workers = 10;
                }
                if gadmm.rho == 24.0 {
                    gadmm.rho = DNN_RHO;
                }
                if gadmm.dual_step == 1.0 {
                    // Sec. V-B: α-damped dual update for the non-convex task.
                    gadmm.dual_step = DNN_ALPHA;
                }
                // Paper: 8-bit quantizer for the DNN task, every
                // quantizing scheme.
                if let crate::config::CompressorConfig::Stochastic(q)
                | crate::config::CompressorConfig::Censored { quant: q, .. } =
                    &mut gadmm.compressor
                {
                    if q.bits == 2 {
                        q.bits = DNN_BITS;
                    }
                }
                // A still-default iteration budget (tuned for the linreg
                // sweeps) re-defaults to the DNN scale; an explicit
                // --iters always wins.
                if iterations == ExperimentConfig::default().iterations {
                    iterations = 500;
                }
                eval_default = 5;
                stop_above = Some(cfg.accuracy_target);
            }
            ProblemKind::LogReg => {
                if gadmm.workers == 50 {
                    gadmm.workers = 10;
                }
                if gadmm.rho == 24.0 {
                    gadmm.rho = LOGREG_RHO;
                }
                eval_default = 1;
                stop_above = Some(cfg.accuracy_target);
            }
        }
        let opts = self.opts_override.clone().unwrap_or(RunOptions {
            iterations,
            eval_every: cfg.eval_every.unwrap_or(eval_default),
            stop_below,
            stop_above,
            rho_policy: cfg.rho_policy,
        });
        Resolved {
            problem: cfg.problem,
            driver: cfg.driver,
            topology: cfg.topology,
            gadmm,
            sim: cfg.sim.clone(),
            tcp: cfg.tcp.clone(),
            opts,
            seed: cfg.seed,
            scale_dims: cfg.scale_dims,
            quick: self.quick,
        }
    }

    /// Instantiate the registry entry for a resolved session.
    fn build_problem(r: &Resolved) -> Box<dyn SessionProblem> {
        let n = r.gadmm.workers;
        match r.problem {
            ProblemKind::LinReg => {
                let spec = if r.quick {
                    LinRegSpec {
                        samples: 2_000,
                        ..LinRegSpec::default()
                    }
                } else {
                    LinRegSpec::default()
                };
                let data = LinRegDataset::synthesize(&spec, r.seed);
                let (_, f_star) = data.optimum();
                let partition = Partition::contiguous(data.samples(), n);
                let problem = LinRegProblem::new(&data, &partition, r.gadmm.rho);
                Box::new(LinRegSession { problem, f_star })
            }
            ProblemKind::DiagLinReg => {
                let dims = if r.quick {
                    r.scale_dims.min(1_024)
                } else {
                    r.scale_dims
                };
                let problem = DiagLinRegProblem::synthesize(dims, n, r.seed);
                let (_, f_star) = problem.optimum();
                Box::new(DiagLinRegSession { problem, f_star })
            }
            ProblemKind::Mlp => {
                let spec = if r.quick {
                    ImageSpec {
                        train: 2_000,
                        test: 600,
                        ..ImageSpec::default()
                    }
                } else {
                    ImageSpec::default()
                };
                let data = ImageDataset::synthesize(&spec, r.seed);
                let partition = Partition::contiguous(data.train_len(), n);
                let problem =
                    MlpProblem::new(&data, &partition, MlpDims::paper(), r.seed ^ 0xD1A);
                let init = problem.initial_theta(r.seed ^ 0x1517);
                Box::new(MlpSession { problem, init })
            }
            ProblemKind::LogReg => {
                let spec = if r.quick {
                    LogRegSpec {
                        samples: 800,
                        test: 300,
                        ..LogRegSpec::default()
                    }
                } else {
                    LogRegSpec::default()
                };
                let problem = LogRegProblem::synthesize(&spec, n, r.seed);
                Box::new(LogRegSession { problem })
            }
        }
    }

    /// Resolve, validate, and instantiate the configured driver. The
    /// returned trait object can be driven manually with custom
    /// [`RunOptions`]; [`Session::run`] is the one-call path.
    pub fn into_driver(self) -> anyhow::Result<Box<dyn Driver>> {
        let r = self.resolve();
        r.opts.validate().map_err(|e: InvalidRunOptions| anyhow::anyhow!(e))?;
        // `hier:` topologies keep their grouped layout alongside the flat
        // bipartite graph: the sim driver shards its event queue and
        // re-stitches group-locally from it; the lock-step drivers run the
        // flat graph (the math only sees the bipartite edge list).
        let (topo, hier) = match r.topology {
            TopologyKind::Hier { groups, inner } => {
                let h = HierTopology::build(r.gadmm.workers, groups, inner)?;
                (h.topo, Some(h.layout))
            }
            k => (k.build(r.gadmm.workers, r.seed)?, None),
        };
        let problem = Self::build_problem(&r);
        assert_eq!(
            problem.workers(),
            r.gadmm.workers,
            "registry problem size must match the session's worker count"
        );
        // Per-block compressor specs must match the problem's actual
        // block structure — a typo'd or missing block name is a typed
        // config error here, before any driver is built.
        r.gadmm
            .compressor
            .validate_blocks(&problem.block_layout())
            .map_err(|why| {
                anyhow::anyhow!("compressor does not fit problem {}: {why}", r.problem.name())
            })?;
        Ok(match r.driver {
            DriverKind::Engine => Box::new(EngineDriver::new(
                r.gadmm.clone(),
                problem,
                topo,
                r.seed,
            )),
            DriverKind::Threaded => {
                // The threaded runtime maps solver p onto position p; all
                // TopologyKind constructors are identity-ordered, so this
                // is a guard against future non-identity constructors.
                for p in 0..topo.len() {
                    anyhow::ensure!(
                        topo.worker_at(p) == p,
                        "threaded sessions require identity position order"
                    );
                }
                Box::new(ThreadedDriver {
                    cfg: r.gadmm.clone(),
                    topo,
                    seed: r.seed,
                    problem: Some(problem),
                })
            }
            DriverKind::Sim => {
                // Deterministic collinear deployment (50 m spacing) — the
                // same geometry the sim equivalence suites pin.
                let points = collinear(r.gadmm.workers, 50.0);
                let mut driver = SimDriver::new(
                    r.gadmm.clone(),
                    r.sim.clone(),
                    problem,
                    topo,
                    points,
                    r.seed,
                );
                if let Some(layout) = hier {
                    driver.install_hier(layout);
                }
                Box::new(driver)
            }
            DriverKind::Tcp => {
                // Like the threaded runtime, the tcp harness maps solver p
                // onto position p.
                for p in 0..topo.len() {
                    anyhow::ensure!(
                        topo.worker_at(p) == p,
                        "tcp sessions require identity position order"
                    );
                }
                // Same collinear geometry as the sim driver, so the shared
                // membership layer re-stitches both over identical
                // nearest-neighbor chains (the tcp-vs-sim dropout
                // equivalence suite depends on it).
                let points = collinear(r.gadmm.workers, 50.0);
                Box::new(TcpDriver {
                    cfg: r.gadmm.clone(),
                    topo,
                    seed: r.seed,
                    tcp: r.tcp.clone(),
                    dropouts: r.sim.dropouts.clone(),
                    points,
                    problem: Some(problem),
                })
            }
        })
    }

    /// Resolve, build, run, and return the unified [`RunSummary`].
    pub fn run(self) -> anyhow::Result<RunSummary> {
        self.run_observed(&mut NoopObserver)
    }

    /// [`Session::run`] with a streaming [`Observer`]. When telemetry
    /// exporters are configured, the observer is wrapped in a collecting
    /// tee and the trace files are written after the run.
    pub fn run_observed(self, observer: &mut dyn Observer) -> anyhow::Result<RunSummary> {
        let opts = self.resolve().opts;
        let telemetry = self.telemetry.clone();
        let mut driver = self.into_driver()?;
        if !telemetry.enabled() {
            return driver.run(&opts, observer);
        }
        let mut tee = TelemetryTee {
            inner: observer,
            records: Vec::new(),
        };
        let summary = driver.run(&opts, &mut tee)?;
        let records = tee.records;
        if let Some(path) = &telemetry.jsonl {
            write_jsonl(path, &records)
                .with_context(|| format!("writing JSONL trace to {}", path.display()))?;
        }
        if let Some(path) = &telemetry.chrome {
            write_chrome_trace(path, &records)
                .with_context(|| format!("writing Chrome trace to {}", path.display()))?;
        }
        Ok(summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CompressorConfig;

    #[test]
    fn problem_and_driver_kinds_parse_and_reject() {
        assert_eq!(ProblemKind::parse("linreg").unwrap(), ProblemKind::LinReg);
        assert_eq!(ProblemKind::parse("scale").unwrap(), ProblemKind::DiagLinReg);
        assert_eq!(ProblemKind::parse("dnn").unwrap(), ProblemKind::Mlp);
        assert_eq!(ProblemKind::parse("logreg").unwrap(), ProblemKind::LogReg);
        let err = ProblemKind::parse("svm").unwrap_err();
        assert!(err.contains("svm") && err.contains("logreg"), "{err}");

        assert_eq!(DriverKind::parse("engine").unwrap(), DriverKind::Engine);
        assert_eq!(DriverKind::parse("threaded").unwrap(), DriverKind::Threaded);
        assert_eq!(DriverKind::parse("sim").unwrap(), DriverKind::Sim);
        assert_eq!(DriverKind::parse("tcp").unwrap(), DriverKind::Tcp);
        assert_eq!(DriverKind::parse("sockets").unwrap(), DriverKind::Tcp);
        // Unknown names cite the offending value and the whole valid set.
        let err = DriverKind::parse("gpu").unwrap_err();
        assert!(err.contains("gpu") && err.contains("sim"), "{err}");
        assert!(err.contains("engine") && err.contains("threaded"), "{err}");
        assert!(err.contains("tcp"), "{err}");
    }

    #[test]
    fn per_problem_redefaults_resolve_like_the_old_subcommands() {
        // Un-overridden defaults re-resolve per problem…
        let s = Session::new(ProblemKind::Mlp);
        let r = s.resolve();
        assert_eq!(r.gadmm.workers, 10);
        assert_eq!(r.gadmm.rho, crate::figures::helpers::DNN_RHO);
        assert_eq!(r.gadmm.dual_step, crate::figures::helpers::DNN_ALPHA);
        assert_eq!(r.gadmm.compressor.quant().unwrap().bits, 8);
        assert_eq!(r.opts.eval_every, 5);
        assert!(r.opts.stop_above.is_some() && r.opts.stop_below.is_none());

        let r = Session::new(ProblemKind::LinReg).resolve();
        assert_eq!(r.gadmm.workers, 50);
        assert_eq!(r.gadmm.rho, crate::figures::helpers::LINREG_RHO);
        assert!(r.opts.stop_below.is_some() && r.opts.stop_above.is_none());

        let r = Session::new(ProblemKind::DiagLinReg).resolve();
        assert_eq!(r.gadmm.workers, 16);
        assert_eq!(r.gadmm.rho, 4.0);
        assert_eq!(r.opts.eval_every, 10);

        let r = Session::new(ProblemKind::LogReg).resolve();
        assert_eq!(r.gadmm.workers, 10);
        assert_eq!(r.gadmm.rho, LOGREG_RHO);

        // The default iteration budget re-defaults to the DNN scale…
        let r = Session::new(ProblemKind::Mlp).resolve();
        assert_eq!(r.opts.iterations, 500);

        // …while explicit settings always win.
        let r = Session::new(ProblemKind::Mlp)
            .workers(6)
            .rho(2.5)
            .eval_every(3)
            .iterations(1_200)
            .resolve();
        assert_eq!(r.gadmm.workers, 6);
        assert_eq!(r.gadmm.rho, 2.5);
        assert_eq!(r.opts.eval_every, 3);
        assert_eq!(r.opts.iterations, 1_200, "explicit --iters must not be capped");
    }

    #[test]
    fn invalid_options_surface_as_typed_errors_before_any_work() {
        let err = Session::new(ProblemKind::LinReg)
            .quick(true)
            .options(RunOptions {
                iterations: 10,
                eval_every: 0,
                ..RunOptions::default()
            })
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("eval_every"), "{err}");
    }

    #[test]
    fn hier_topology_builds_on_every_local_driver() {
        // hier:3 over 12 workers: engine and threaded run the flat
        // bipartite graph; the sim driver additionally installs the
        // grouped layout (sharded queue + group-local restitch).
        for driver in [DriverKind::Engine, DriverKind::Threaded, DriverKind::Sim] {
            let summary = Session::new(ProblemKind::LinReg)
                .quick(true)
                .workers(12)
                .driver(driver)
                .topology(TopologyKind::parse("hier:3").unwrap())
                .iterations(30)
                .eval_every(5)
                .seed(7)
                .run()
                .unwrap();
            assert!(summary.final_value().is_finite());
            assert_eq!(summary.thetas.len(), 12);
        }
    }

    #[test]
    fn session_runs_linreg_on_the_engine() {
        let summary = Session::new(ProblemKind::LinReg)
            .quick(true)
            .workers(6)
            .iterations(400)
            .seed(3)
            .run()
            .unwrap();
        assert_eq!(summary.driver, "engine");
        assert!(summary.final_value().is_finite());
        // stop_below = loss_target (1e-4) must early-stop the run.
        assert!(summary.iterations_run <= 400);
        assert!(!summary.recorder.points.is_empty());
        assert_eq!(summary.thetas.len(), 6);
    }

    #[test]
    fn session_runs_logreg_on_every_driver_to_target() {
        for kind in [
            DriverKind::Engine,
            DriverKind::Threaded,
            DriverKind::Sim,
            DriverKind::Tcp,
        ] {
            let summary = Session::new(ProblemKind::LogReg)
                .quick(true)
                .workers(4)
                .driver(kind)
                .compressor(CompressorConfig::FullPrecision)
                .iterations(60)
                .seed(5)
                .run()
                .unwrap_or_else(|e| panic!("{} failed: {e}", kind.name()));
            assert!(
                summary.final_value() >= 0.9,
                "{}: accuracy {} below target",
                kind.name(),
                summary.final_value()
            );
            assert!(
                summary.iterations_run < 60,
                "{}: expected accuracy early stop, ran {}",
                kind.name(),
                summary.iterations_run
            );
        }
    }

    #[test]
    fn threaded_sessions_run_once() {
        let session = Session::new(ProblemKind::LinReg)
            .quick(true)
            .workers(4)
            .driver(DriverKind::Threaded)
            .iterations(5);
        let opts = session.resolved_options();
        let mut driver = session.into_driver().unwrap();
        assert_eq!(driver.kind(), DriverKind::Threaded);
        driver.run(&opts, &mut NoopObserver).unwrap();
        let err = driver.run(&opts, &mut NoopObserver).unwrap_err();
        assert!(err.to_string().contains("only run once"), "{err}");
    }

    #[cfg(feature = "telemetry")]
    #[test]
    fn session_writes_trace_exports_and_metrics() {
        let dir = std::env::temp_dir();
        let jsonl = dir.join("qgadmm_session_trace_test.jsonl");
        let chrome = dir.join("qgadmm_session_trace_test.chrome.json");
        let summary = Session::new(ProblemKind::LinReg)
            .quick(true)
            .workers(4)
            .seed(3)
            .options(RunOptions {
                iterations: 3,
                eval_every: 1,
                ..RunOptions::default()
            })
            .telemetry(TelemetryOptions::jsonl(&jsonl).with_chrome(&chrome))
            .run()
            .unwrap();
        assert_eq!(summary.metrics.counter("broadcasts"), Some(4 * 3));
        let text = std::fs::read_to_string(&jsonl).unwrap();
        // 12 span/compress records per iteration plus one eval each.
        assert_eq!(text.lines().count(), 3 * 13);
        let chrome_text = std::fs::read_to_string(&chrome).unwrap();
        assert!(chrome_text.contains("traceEvents"), "{chrome_text}");
        let _ = std::fs::remove_file(&jsonl);
        let _ = std::fs::remove_file(&chrome);
    }

    #[test]
    fn per_block_spec_with_unknown_block_is_a_typed_error() {
        let comp = CompressorConfig::parse(
            "layers:w1=stochastic@4,bogus=full",
            crate::config::QuantConfig::default(),
        )
        .unwrap();
        let err = Session::new(ProblemKind::LinReg)
            .quick(true)
            .workers(4)
            .compressor(comp)
            .run()
            .unwrap_err()
            .to_string();
        // The error must name the problem, the offending block, and the
        // valid block names.
        assert!(err.contains("linreg"), "{err}");
        assert!(err.contains("w1") || err.contains("bogus"), "{err}");
        assert!(err.contains("all"), "{err}");
    }

    #[test]
    fn single_block_layers_spec_matches_flat_run_through_the_session() {
        let flat = Session::new(ProblemKind::LinReg)
            .quick(true)
            .workers(4)
            .iterations(30)
            .seed(9)
            .run()
            .unwrap();
        // `layers:all=stochastic@2` goes through the genuine per-block
        // composition (Blocks compressor, v3 frames) yet must reproduce
        // the flat stochastic default bit-for-bit.
        let comp =
            CompressorConfig::parse("layers:all=stochastic@2", crate::config::QuantConfig::default())
                .unwrap();
        let layered = Session::new(ProblemKind::LinReg)
            .quick(true)
            .workers(4)
            .compressor(comp)
            .iterations(30)
            .seed(9)
            .run()
            .unwrap();
        assert_eq!(flat.comm.bits, layered.comm.bits);
        assert_eq!(flat.thetas, layered.thetas);
        assert_eq!(flat.final_value().to_bits(), layered.final_value().to_bits());
    }

    #[test]
    fn rho_policy_threads_from_config_into_run_options() {
        let opts = Session::new(ProblemKind::LinReg)
            .rho_policy(RhoPolicy::residual_balance())
            .resolved_options();
        assert_eq!(opts.rho_policy, RhoPolicy::residual_balance());
        // Adaptive ρ yields a different (still convergent) trajectory.
        let fixed = Session::new(ProblemKind::LinReg)
            .quick(true)
            .workers(4)
            .iterations(30)
            .seed(11)
            .run()
            .unwrap();
        let adaptive = Session::new(ProblemKind::LinReg)
            .quick(true)
            .workers(4)
            .iterations(30)
            .seed(11)
            .rho_policy(RhoPolicy::residual_balance())
            .run()
            .unwrap();
        assert!(adaptive.final_value().is_finite());
        assert!(
            !adaptive.residuals.is_empty(),
            "adaptive runs must report residual points"
        );
        let _ = fixed;
    }

    #[test]
    fn describe_names_every_axis() {
        let text = Session::new(ProblemKind::LogReg)
            .driver(DriverKind::Sim)
            .describe();
        assert!(text.contains("problem=logreg"), "{text}");
        assert!(text.contains("driver=sim"), "{text}");
        assert!(text.contains("topology=line"), "{text}");
    }
}
