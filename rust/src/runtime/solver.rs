//! XLA-backed local problems: the same [`LocalProblem`] contract as the
//! native backend, but every primal update executes the AOT artifact
//! through PJRT — the flagship three-layer path (L3 Rust engine → L2 jax
//! graph → L1 Pallas kernels, compiled once at build time).
//!
//! Objective evaluation (metrics only, not on the request path) stays
//! native. The quantizer runs natively inside the engine on both backends
//! — it is a sub-microsecond elementwise pass, and the `squant_*`
//! artifacts exist so the parity suite can pin the native implementation
//! to the Pallas kernel bit-for-bit (same uniforms ⇒ same levels).

use super::{Artifact, Runtime, RuntimeError};
use crate::data::images::{ImageDataset, PIXELS};
use crate::data::linreg::{LinRegDataset, WorkerStats};
use crate::data::partition::Partition;
use crate::model::mlp::MlpDims;
use crate::model::{LocalProblem, NeighborCtx};
use crate::util::rng::Rng;
use std::rc::Rc;

/// A degree-general [`NeighborCtx`] mapped onto the chain-shaped
/// (left, right) input slots the AOT artifacts are compiled for.
struct ChainSlots<'a> {
    lambda_left: Option<&'a [f32]>,
    theta_left: Option<&'a [f32]>,
    lambda_right: Option<&'a [f32]>,
    theta_right: Option<&'a [f32]>,
}

/// Split a context into chain slots. The artifacts hard-wire one `+λ` and
/// one `−λ` penalty slot (eqs. (14)–(17) on a chain), so degree ≤ 2 with
/// at most one link per sign maps exactly; anything else — a star hub, a
/// dense random-bipartite node — cannot execute through XLA and fails
/// with a clear [`RuntimeError::Unsupported`] instead of computing
/// garbage. Chains and even rings always satisfy the constraint.
fn chain_slots<'a>(artifact: &str, ctx: &NeighborCtx<'a>) -> Result<ChainSlots<'a>, RuntimeError> {
    let mut left: Option<(&'a [f32], &'a [f32])> = None;
    let mut right: Option<(&'a [f32], &'a [f32])> = None;
    for link in ctx.links {
        let slot = if link.sign > 0.0 { &mut left } else { &mut right };
        if slot.is_some() {
            return Err(RuntimeError::Unsupported(format!(
                "artifact {artifact:?} is compiled for chain neighbor contexts \
                 (at most one link per λ sign); this worker has degree {} with \
                 two links on the same side — use the native backend for \
                 non-chain topologies",
                ctx.degree()
            )));
        }
        *slot = Some((link.lambda, link.theta));
    }
    Ok(ChainSlots {
        lambda_left: left.map(|(l, _)| l),
        theta_left: left.map(|(_, t)| t),
        lambda_right: right.map(|(l, _)| l),
        theta_right: right.map(|(_, t)| t),
    })
}

/// Linear-regression local problem solved through the `linreg_local_d{d}`
/// artifact.
pub struct XlaLinRegProblem {
    artifact: Rc<Artifact>,
    stats: Vec<WorkerStats>,
    /// Per-worker A as flat f32 (artifact input layout).
    a_f32: Vec<Vec<f32>>,
    b_f32: Vec<Vec<f32>>,
    dims: usize,
    zeros: Vec<f32>,
}

impl XlaLinRegProblem {
    pub fn new(
        rt: &Runtime,
        data: &LinRegDataset,
        partition: &Partition,
    ) -> Result<XlaLinRegProblem, RuntimeError> {
        let d = data.features();
        let artifact = rt.artifact(&format!("linreg_local_d{d}"))?;
        let stats: Vec<WorkerStats> = (0..partition.workers())
            .map(|w| {
                let (lo, hi) = partition.bounds(w);
                data.sufficient_stats(lo, hi)
            })
            .collect();
        let a_f32 = stats.iter().map(|s| s.a.to_f32()).collect();
        let b_f32 = stats
            .iter()
            .map(|s| s.b.iter().map(|&x| x as f32).collect())
            .collect();
        Ok(XlaLinRegProblem {
            artifact,
            stats,
            a_f32,
            b_f32,
            dims: d,
            zeros: vec![0.0; d],
        })
    }
}

impl LocalProblem for XlaLinRegProblem {
    fn dims(&self) -> usize {
        self.dims
    }

    fn workers(&self) -> usize {
        self.stats.len()
    }

    fn solve(&mut self, worker: usize, ctx: &NeighborCtx<'_>, out: &mut [f32]) {
        let slots = chain_slots("linreg_local", ctx).unwrap_or_else(|e| panic!("{e}"));
        let z = &self.zeros;
        let mask_l = [f32::from(slots.theta_left.is_some())];
        let mask_r = [f32::from(slots.theta_right.is_some())];
        let rho = [ctx.rho];
        let outs = self
            .artifact
            .call(&[
                &self.a_f32[worker],
                &self.b_f32[worker],
                slots.lambda_left.unwrap_or(z),
                slots.lambda_right.unwrap_or(z),
                slots.theta_left.unwrap_or(z),
                slots.theta_right.unwrap_or(z),
                &mask_l,
                &mask_r,
                &rho,
            ])
            .expect("linreg artifact execution failed");
        out.copy_from_slice(&outs[0]);
    }

    fn objective(&self, worker: usize, theta: &[f32]) -> f64 {
        let t64: Vec<f64> = theta.iter().map(|&x| x as f64).collect();
        self.stats[worker].objective(&t64)
    }
}

/// DNN local problem (Q-SGADMM) solved through the `mlp_local` artifact:
/// one PJRT execution = minibatch forward/backward × 10 Adam steps, all
/// fused into a single compiled module.
pub struct XlaMlpProblem {
    artifact: Rc<Artifact>,
    dims: MlpDims,
    batch: usize,
    shards: Vec<(Vec<f32>, Vec<u8>)>,
    rngs: Vec<Rng>,
    minibatch_x: Vec<f32>,
    minibatch_y: Vec<f32>, // one-hot, artifact input layout
    test_x: Vec<f32>,
    test_y: Vec<u8>,
    zeros: Vec<f32>,
}

impl XlaMlpProblem {
    pub fn new(
        rt: &Runtime,
        data: &ImageDataset,
        partition: &Partition,
        seed: u64,
    ) -> Result<XlaMlpProblem, RuntimeError> {
        let artifact = rt.artifact("mlp_local")?;
        let dims = MlpDims::paper();
        let batch = artifact
            .meta()
            .constants
            .get("batch")
            .map(|&b| b as usize)
            .unwrap_or(100);
        let mut root = Rng::seed_from_u64(seed);
        let shards = (0..partition.workers())
            .map(|w| {
                let idx = partition.shard(w);
                let mut x = Vec::with_capacity(idx.len() * PIXELS);
                let mut y = Vec::with_capacity(idx.len());
                for &i in idx {
                    x.extend_from_slice(data.train_row(i));
                    y.push(data.train_y[i]);
                }
                (x, y)
            })
            .collect::<Vec<_>>();
        let rngs = (0..partition.workers()).map(|w| root.fork(w as u64)).collect();
        Ok(XlaMlpProblem {
            artifact,
            dims,
            batch,
            shards,
            rngs,
            minibatch_x: vec![0.0; batch * PIXELS],
            minibatch_y: vec![0.0; batch * 10],
            test_x: data.test_x.clone(),
            test_y: data.test_y.clone(),
            zeros: vec![0.0; dims.dims()],
        })
    }

    pub fn initial_theta(&self, seed: u64) -> Vec<f32> {
        self.dims.init_theta(&mut Rng::seed_from_u64(seed))
    }

    /// Test accuracy of the worker-averaged model (native forward).
    pub fn average_model_accuracy(&self, thetas: &[Vec<f32>]) -> f64 {
        let d = self.dims.dims();
        let mut avg = vec![0.0f32; d];
        for t in thetas {
            for i in 0..d {
                avg[i] += t[i];
            }
        }
        let n = thetas.len() as f32;
        avg.iter_mut().for_each(|v| *v /= n);
        crate::model::mlp::accuracy(&self.dims, &avg, &self.test_x, &self.test_y)
    }
}

impl LocalProblem for XlaMlpProblem {
    fn dims(&self) -> usize {
        self.dims.dims()
    }

    fn workers(&self) -> usize {
        self.shards.len()
    }

    fn solve(&mut self, worker: usize, ctx: &NeighborCtx<'_>, out: &mut [f32]) {
        let slots = chain_slots("mlp_local", ctx).unwrap_or_else(|e| panic!("{e}"));
        // Sample the minibatch natively (data marshalling, not compute).
        let (sx, sy) = &self.shards[worker];
        let rng = &mut self.rngs[worker];
        let n = sy.len();
        self.minibatch_y.iter_mut().for_each(|v| *v = 0.0);
        for s in 0..self.batch {
            let i = rng.below(n);
            self.minibatch_x[s * PIXELS..(s + 1) * PIXELS]
                .copy_from_slice(&sx[i * PIXELS..(i + 1) * PIXELS]);
            self.minibatch_y[s * 10 + sy[i] as usize] = 1.0;
        }
        let z = &self.zeros;
        let mask_l = [f32::from(slots.theta_left.is_some())];
        let mask_r = [f32::from(slots.theta_right.is_some())];
        let rho = [ctx.rho];
        let outs = self
            .artifact
            .call(&[
                out,
                &self.minibatch_x,
                &self.minibatch_y,
                slots.lambda_left.unwrap_or(z),
                slots.lambda_right.unwrap_or(z),
                slots.theta_left.unwrap_or(z),
                slots.theta_right.unwrap_or(z),
                &mask_l,
                &mask_r,
                &rho,
            ])
            .expect("mlp_local artifact execution failed");
        out.copy_from_slice(&outs[0]);
    }

    fn objective(&self, worker: usize, theta: &[f32]) -> f64 {
        // Mean CE over a capped shard slice (native; metrics only).
        use crate::model::mlp::{ce_loss, forward, MlpScratch};
        let (sx, sy) = &self.shards[worker];
        let n = sy.len().min(256);
        let mut scratch = MlpScratch::new(&self.dims, n);
        forward(&self.dims, theta, &sx[..n * PIXELS], &mut scratch);
        ce_loss(&self.dims, &scratch, &sy[..n]) * sy.len() as f64
    }
}

/// Thin wrapper over a `squant_d*_b*` artifact for the parity tests and
/// the XLA quickstart: quantize `theta` against `theta_hat` with caller-
/// provided uniforms, returning `(levels, theta_hat_new, radius)`.
pub struct XlaQuantizer {
    artifact: Rc<Artifact>,
    dims: usize,
}

impl XlaQuantizer {
    pub fn new(rt: &Runtime, dims: usize, bits: u8) -> Result<XlaQuantizer, RuntimeError> {
        Ok(XlaQuantizer {
            artifact: rt.artifact(&format!("squant_d{dims}_b{bits}"))?,
            dims,
        })
    }

    pub fn quantize(
        &self,
        theta: &[f32],
        theta_hat: &[f32],
        uniforms: &[f32],
    ) -> Result<(Vec<u32>, Vec<f32>, f32), RuntimeError> {
        assert_eq!(theta.len(), self.dims);
        let outs = self.artifact.call(&[theta, theta_hat, uniforms])?;
        let levels = outs[0].iter().map(|&q| q as u32).collect();
        let radius = outs[2][0];
        Ok((levels, outs[1].clone(), radius))
    }
}
