//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes them
//! from the L3 hot path through the `xla` crate's PJRT CPU client.
//!
//! Python is *never* on this path — the manifest + HLO text are the whole
//! interface. Artifact shapes are validated against the manifest at load
//! time and call sites are shape-checked on every invocation.

pub mod session;
pub mod solver;

use crate::util::json::Json;
// BTreeMap, not HashMap: every map on a driver-reachable path iterates in
// a deterministic (sorted) order by construction, so manifest walks can
// never perturb bit-for-bit cross-driver equivalence. Enforced by the
// tidy `determinism-collections` lint (`cargo run --bin tidy`).
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Runtime failure modes.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("artifact {0:?} not found (run `make artifacts`)")]
    NotFound(String),
    #[error("artifact {name:?}: input {index} has {got} elements, want shape {want:?}")]
    BadInput {
        name: String,
        index: usize,
        got: usize,
        want: Vec<usize>,
    },
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("unsupported on the XLA backend: {0}")]
    Unsupported(String),
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
    pub constants: BTreeMap<String, f64>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, RuntimeError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, RuntimeError> {
        let doc = Json::parse(text).map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        let format = doc.get("format").and_then(|f| f.as_str()).unwrap_or("");
        if format != "hlo-text-v1" {
            return Err(RuntimeError::Manifest(format!(
                "unsupported manifest format {format:?}"
            )));
        }
        let arts = doc
            .get("artifacts")
            .ok_or_else(|| RuntimeError::Manifest("missing 'artifacts'".into()))?;
        let Json::Obj(map) = arts else {
            return Err(RuntimeError::Manifest("'artifacts' not an object".into()));
        };
        let mut artifacts = BTreeMap::new();
        for (name, meta) in map {
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>, RuntimeError> {
                meta.get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| RuntimeError::Manifest(format!("{name}: missing {key}")))?
                    .iter()
                    .map(|s| {
                        s.as_arr()
                            .ok_or_else(|| {
                                RuntimeError::Manifest(format!("{name}: bad shape in {key}"))
                            })?
                            .iter()
                            .map(|d| {
                                d.as_usize().ok_or_else(|| {
                                    RuntimeError::Manifest(format!("{name}: bad dim in {key}"))
                                })
                            })
                            .collect()
                    })
                    .collect()
            };
            let file = meta
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| RuntimeError::Manifest(format!("{name}: missing file")))?;
            let mut constants = BTreeMap::new();
            if let Some(Json::Obj(cs)) = meta.get("constants") {
                for (k, v) in cs {
                    if let Some(x) = v.as_f64() {
                        constants.insert(k.clone(), x);
                    }
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: shapes("inputs")?,
                    outputs: shapes("outputs")?,
                    constants,
                },
            );
        }
        Ok(Manifest { artifacts })
    }
}

/// A compiled artifact ready to execute.
pub struct Artifact {
    meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Execute with f32 buffers in manifest input order. Returns the
    /// outputs as flat f32 vectors (manifest output order).
    pub fn call(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>, RuntimeError> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(RuntimeError::BadInput {
                name: self.meta.name.clone(),
                index: inputs.len(),
                got: inputs.len(),
                want: vec![self.meta.inputs.len()],
            });
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, shape)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                return Err(RuntimeError::BadInput {
                    name: self.meta.name.clone(),
                    index: i,
                    got: buf.len(),
                    want: shape.clone(),
                });
            }
            let lit = if shape.is_empty() {
                xla::Literal::scalar(buf[0])
            } else {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(buf).reshape(&dims)?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// The artifact registry: PJRT client + lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: std::cell::RefCell<BTreeMap<String, std::rc::Rc<Artifact>>>,
}

impl Runtime {
    /// Load the manifest and create the CPU PJRT client. Executables are
    /// compiled on first use (compile time for the MLP local step is
    /// nontrivial; figure runs that only need linreg shouldn't pay it).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime, RuntimeError> {
        let manifest = Manifest::load(dir.as_ref())?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            compiled: std::cell::RefCell::new(BTreeMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling if necessary) an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<std::rc::Rc<Artifact>, RuntimeError> {
        if let Some(a) = self.compiled.borrow().get(name) {
            return Ok(a.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| RuntimeError::NotFound(name.to_string()))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(
            meta.file
                .to_str()
                .ok_or_else(|| RuntimeError::Manifest("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let artifact = std::rc::Rc::new(Artifact { meta, exe });
        self.compiled
            .borrow_mut()
            .insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Default artifact directory: `$QGADMM_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("QGADMM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// True if a manifest exists at the default location.
    pub fn available() -> bool {
        Self::default_dir().join("manifest.json").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
            "format": "hlo-text-v1",
            "artifacts": {
                "squant_d6_b2": {
                    "file": "squant_d6_b2.hlo.txt",
                    "inputs": [[6], [6], [6]],
                    "outputs": [[6], [6], []],
                    "constants": {"bits": 2, "dims": 6}
                }
            }
        }"#;
        let m = Manifest::parse(text, Path::new("/tmp/x")).unwrap();
        let a = &m.artifacts["squant_d6_b2"];
        assert_eq!(a.inputs, vec![vec![6], vec![6], vec![6]]);
        assert_eq!(a.outputs[2], Vec::<usize>::new());
        assert_eq!(a.constants["bits"], 2.0);
        assert_eq!(a.file, Path::new("/tmp/x/squant_d6_b2.hlo.txt"));
    }

    #[test]
    fn manifest_rejects_bad_format() {
        let text = r#"{"format": "v999", "artifacts": {}}"#;
        assert!(matches!(
            Manifest::parse(text, Path::new(".")),
            Err(RuntimeError::Manifest(_))
        ));
    }
}
