//! System-level convergence properties across the algorithm family —
//! Theorem 2's guarantees and the paper's headline comparisons, exercised
//! end-to-end on the native backend.

use qgadmm::baselines::gd::{run_gd_linreg, GdOptions};
use qgadmm::baselines::QuantMode;
use qgadmm::config::{GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::net::geometry::Area;
use qgadmm::net::topology::Topology;
use qgadmm::testing::property;
use qgadmm::util::rng::Rng;

const RHO: f32 = 1600.0;

fn data(seed: u64) -> LinRegDataset {
    LinRegDataset::synthesize(
        &LinRegSpec {
            samples: 2_000,
            ..LinRegSpec::default()
        },
        seed,
    )
}

fn engine(
    data: &LinRegDataset,
    workers: usize,
    quant: Option<QuantConfig>,
    topo: Topology,
    seed: u64,
) -> (GadmmEngine<LinRegProblem>, f64) {
    let partition = Partition::contiguous(data.samples(), workers);
    let problem = LinRegProblem::new(data, &partition, RHO);
    let cfg = GadmmConfig {
        workers,
        rho: RHO,
        dual_step: 1.0,
        compressor: quant.into(),
        threads: 0,
    };
    let (_, f_star) = data.optimum();
    (GadmmEngine::new(cfg, problem, topo, seed), f_star)
}

#[test]
fn qgadmm_tracks_gadmm_iteration_for_iteration() {
    // Paper headline: Q-GADMM converges as fast as GADMM per iteration.
    // Uses the figure-default ρ (6400): at that operating point the
    // 2-bit trajectory tracks full precision within ~25% (see
    // examples/probe sweeps); under-damped ρ exaggerates the early
    // quantization-noise phase.
    let ds = data(1);
    let workers = 8;
    let partition = Partition::contiguous(ds.samples(), workers);
    let rho = 6400.0f32;
    let mk = |quant| {
        let problem = LinRegProblem::new(&ds, &partition, rho);
        GadmmEngine::new(
            GadmmConfig { workers, rho, dual_step: 1.0, compressor: quant.into(), threads: 0 },
            problem,
            Topology::line(workers),
            3,
        )
    };
    let (_, f_star) = ds.optimum();
    let mut q_eng = mk(Some(QuantConfig::default()));
    let mut f_eng = mk(None);
    let mut q_gaps = Vec::new();
    let mut f_gaps = Vec::new();
    for _ in 0..2_000 {
        q_eng.iterate();
        f_eng.iterate();
        q_gaps.push((q_eng.global_objective() - f_star).abs());
        f_gaps.push((f_eng.global_objective() - f_star).abs());
    }
    // Early iterations are dominated by the (still-large) quantization
    // radius; the paper's "same convergence speed" claim is about the
    // annealed regime. Compare at a tight target.
    let target = f_gaps[0] * 1e-7;
    let q_at = q_gaps.iter().position(|&g| g < target);
    let f_at = f_gaps.iter().position(|&g| g < target);
    let (q_at, f_at) = (q_at.expect("Q-GADMM reached"), f_at.expect("GADMM reached"));
    let ratio = q_at as f64 / f_at.max(1) as f64;
    assert!(
        (0.5..1.6).contains(&ratio),
        "Q-GADMM {}, GADMM {} iterations to target (ratio {ratio})",
        q_at,
        f_at
    );
}

#[test]
fn qgadmm_beats_gadmm_on_bits_by_payload_ratio() {
    // Payload: (2·6+64) vs 32·6 bits/broadcast = 4.05x; identical per-
    // iteration convergence (above) makes the end-to-end bit ratio ≈ the
    // payload ratio (the paper's Fig. 6 reports 3.5x on its settings).
    let ds = data(5);
    let workers = 8;
    let target = 1e-3;
    let run = |quant| {
        let (mut eng, f_star) = engine(&ds, workers, quant, Topology::line(workers), 11);
        let opts = RunOptions {
            iterations: 3_000,
            eval_every: 1,
            stop_below: Some(target),
            stop_above: None,
            ..RunOptions::default()
        };
        let rep = eng.run(&opts, |e| (e.global_objective() - f_star).abs());
        rep.recorder.bits_to(target).expect("reached")
    };
    let q_bits = run(Some(QuantConfig::default()));
    let f_bits = run(None);
    let ratio = f_bits as f64 / q_bits as f64;
    assert!(
        (2.0..6.5).contains(&ratio),
        "bits ratio {ratio}: q={q_bits} f={f_bits}"
    );
}

#[test]
fn residuals_vanish_under_quantization_theorem2() {
    let ds = data(7);
    let workers = 10;
    let (mut eng, _) = engine(&ds, workers, Some(QuantConfig::default()), Topology::line(workers), 13);
    let first = eng.iterate();
    let mut last = first;
    for _ in 0..1_200 {
        last = eng.iterate();
    }
    assert!(last.primal_sq < first.primal_sq * 1e-6, "{last:?}");
    assert!(last.dual_sq < first.dual_sq * 1e-6, "{last:?}");
    assert!(last.quant_err_sq < first.quant_err_sq * 1e-6, "{last:?}");
}

#[test]
fn adaptive_bit_rule_converges() {
    let ds = data(9);
    let workers = 6;
    let quant = Some(QuantConfig {
        bits: 2,
        adaptive: true,
        max_bits: 8,
    });
    let (mut eng, f_star) = engine(&ds, workers, quant, Topology::line(workers), 17);
    for _ in 0..1_000 {
        eng.iterate();
    }
    let gap = (eng.global_objective() - f_star).abs();
    assert!(gap < 1e-2, "gap={gap}");
}

#[test]
fn random_geometry_chains_converge() {
    // Property: Q-GADMM converges on the nearest-neighbor chain of any
    // random drop (the topology heuristic never breaks the algorithm).
    property("geometry chains", 5, |rng: &mut Rng| {
        let workers = 4 + rng.below(8);
        let pts = Area::default().drop_workers(workers, rng);
        let topo = Topology::nearest_neighbor_chain(&pts);
        let ds = data(100 + workers as u64);
        let (mut eng, f_star) = engine(&ds, workers, Some(QuantConfig::default()), topo, 19);
        let start = (eng.global_objective() - f_star).abs();
        for _ in 0..800 {
            eng.iterate();
        }
        let gap = (eng.global_objective() - f_star).abs();
        assert!(gap < 1e-2 * start.max(1.0), "N={workers} gap={gap}");
    });
}

#[test]
fn quantized_gd_eventually_matches_gd_loss() {
    // Sanity across families: QGD (memory mode) achieves the same loss
    // levels as GD, just like Q-GADMM vs GADMM.
    let ds = LinRegDataset::synthesize(
        &LinRegSpec {
            samples: 2_000,
            scale_spread: 4.0,
            ..LinRegSpec::default()
        },
        21,
    );
    let gd = run_gd_linreg(
        &ds,
        6,
        &GdOptions {
            iterations: 3_000,
            ..GdOptions::default()
        },
    );
    let qgd = run_gd_linreg(
        &ds,
        6,
        &GdOptions {
            iterations: 3_000,
            quant: Some((QuantConfig::default(), QuantMode::Memory)),
            ..GdOptions::default()
        },
    );
    let g = gd.final_value();
    let q = qgd.final_value();
    assert!(q < 1e3 * g.max(1e-12), "QGD {q} vs GD {g}");
}
