//! Native ↔ XLA backend parity: the same math must come out of the Rust
//! implementations and the AOT-compiled Pallas/JAX artifacts.
//!
//! These tests need `make artifacts`; they skip (with a loud message) when
//! the manifest is missing so `cargo test` stays green on a fresh clone.

use qgadmm::config::{GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::{LinkBuf, LocalProblem};
use qgadmm::net::topology::Topology;
use qgadmm::quant::{BitPolicy, StochasticQuantizer};
use qgadmm::runtime::solver::{XlaLinRegProblem, XlaQuantizer};
use qgadmm::runtime::Runtime;
use qgadmm::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    if !Runtime::available() {
        eprintln!("SKIP: no artifacts at {:?} (run `make artifacts`)", Runtime::default_dir());
        return None;
    }
    Some(Runtime::load(Runtime::default_dir()).expect("artifacts present but unloadable"))
}

#[test]
fn squant_artifact_matches_native_quantizer() {
    let Some(rt) = runtime_or_skip() else { return };
    let d = 6;
    let xq = XlaQuantizer::new(&rt, d, 2).unwrap();
    let mut rng = Rng::seed_from_u64(42);
    let mut mismatch_total = 0usize;
    let mut coords_total = 0usize;
    for trial in 0..50 {
        let mut native = StochasticQuantizer::new(d, BitPolicy::Fixed(2));
        let theta: Vec<f32> = (0..d).map(|_| rng.uniform_f32() * 4.0 - 2.0).collect();
        let hat: Vec<f32> = (0..d).map(|_| rng.uniform_f32() * 4.0 - 2.0).collect();
        let uniforms: Vec<f32> = (0..d).map(|_| rng.uniform_f32()).collect();
        native.reset_to(&hat);
        let msg = native.quantize_with_uniforms(&theta, &uniforms);
        let (levels, hat_new, radius) = xq.quantize(&theta, &hat, &uniforms).unwrap();
        // Radius is an exact max — must agree bit-for-bit.
        assert_eq!(radius, msg.radius, "trial {trial}");
        // Levels may flip by one at FMA-sensitive boundaries (see
        // python/tests/test_squant.py); count but bound the flips.
        for i in 0..d {
            coords_total += 1;
            let diff = (levels[i] as i64 - msg.levels[i] as i64).abs();
            assert!(diff <= 1, "trial {trial} dim {i}: {} vs {}", levels[i], msg.levels[i]);
            if diff != 0 {
                mismatch_total += 1;
            }
        }
        let delta = if msg.radius > 0.0 {
            2.0 * msg.radius / 3.0
        } else {
            0.0
        };
        for i in 0..d {
            assert!(
                (hat_new[i] - native.theta_hat()[i]).abs() <= delta + 1e-6,
                "trial {trial} dim {i}"
            );
        }
    }
    assert!(
        (mismatch_total as f64) < 0.01 * coords_total as f64 + 2.0,
        "too many level flips: {mismatch_total}/{coords_total}"
    );
}

#[test]
fn linreg_artifact_matches_native_solve() {
    let Some(rt) = runtime_or_skip() else { return };
    let spec = LinRegSpec {
        samples: 1_200,
        ..LinRegSpec::default()
    };
    let data = LinRegDataset::synthesize(&spec, 9);
    let workers = 4;
    let partition = Partition::contiguous(data.samples(), workers);
    let rho = 1600.0f32;
    let mut native = LinRegProblem::new(&data, &partition, rho);
    let mut xla = XlaLinRegProblem::new(&rt, &data, &partition).unwrap();
    let mut rng = Rng::seed_from_u64(3);

    for w in 0..workers {
        let d = native.dims();
        let mk = |rng: &mut Rng| -> Vec<f32> {
            (0..d).map(|_| rng.uniform_f32() - 0.5).collect()
        };
        let (lam_l, lam_r, th_l, th_r) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let buf = LinkBuf::chain(
            (w > 0).then_some(lam_l.as_slice()),
            (w > 0).then_some(th_l.as_slice()),
            (w + 1 < workers).then_some(lam_r.as_slice()),
            (w + 1 < workers).then_some(th_r.as_slice()),
        );
        let ctx = buf.ctx(rho);
        let mut out_native = vec![0.0f32; d];
        let mut out_xla = vec![0.0f32; d];
        native.solve(w, &ctx, &mut out_native);
        xla.solve(w, &ctx, &mut out_xla);
        for i in 0..d {
            // Native solves in f64 then narrows; the artifact is f32
            // end-to-end with large (~1e4-scale) Gram entries — compare at
            // f32-appropriate relative tolerance.
            let tol = 1e-3 * (1.0 + out_native[i].abs());
            assert!(
                (out_native[i] - out_xla[i]).abs() <= tol,
                "worker {w} dim {i}: native {} xla {}",
                out_native[i],
                out_xla[i]
            );
        }
    }
}

#[test]
fn engine_converges_identically_on_both_backends() {
    let Some(rt) = runtime_or_skip() else { return };
    let spec = LinRegSpec {
        samples: 1_000,
        ..LinRegSpec::default()
    };
    let data = LinRegDataset::synthesize(&spec, 31);
    let (_, f_star) = data.optimum();
    let workers = 6;
    let partition = Partition::contiguous(data.samples(), workers);
    let rho = 1600.0f32;
    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor: qgadmm::config::CompressorConfig::Stochastic(QuantConfig::default()),
        threads: 0,
    };
    let opts = RunOptions {
        iterations: 1_000,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };

    let native_gap = {
        let problem = LinRegProblem::new(&data, &partition, rho);
        let mut engine = GadmmEngine::new(cfg.clone(), problem, Topology::line(workers), 5);
        let rep = engine.run(&opts, |e| (e.global_objective() - f_star).abs());
        rep.final_loss_gap()
    };
    let xla_gap = {
        let problem = XlaLinRegProblem::new(&rt, &data, &partition).unwrap();
        let mut engine = GadmmEngine::new(cfg, problem, Topology::line(workers), 5);
        let rep = engine.run(&opts, |e| (e.global_objective() - f_star).abs());
        rep.final_loss_gap()
    };
    // Same seeds, near-identical arithmetic: both must converge to the
    // same loss regime (f32 drift compounds over 400 iterations, so this
    // is an order-of-magnitude check, not bit equality).
    assert!(native_gap < 1.0, "native gap {native_gap}");
    assert!(xla_gap < 1.0, "xla gap {xla_gap}");
    assert!(
        (native_gap.log10() - xla_gap.log10()).abs() < 2.0,
        "backends diverged: native {native_gap:.3e} vs xla {xla_gap:.3e}"
    );
}

#[test]
fn mlp_artifacts_match_native_forward_and_grad() {
    let Some(rt) = runtime_or_skip() else { return };
    use qgadmm::model::mlp::{backward, forward, MlpDims, MlpScratch};
    let dims = MlpDims::paper();
    let d = dims.dims();
    let mut rng = Rng::seed_from_u64(77);
    let theta = dims.init_theta(&mut rng);
    let batch = 100;
    let mut x = vec![0.0f32; batch * dims.input];
    rng.fill_uniform_f32(&mut x);
    let labels: Vec<u8> = (0..batch).map(|_| rng.below(10) as u8).collect();
    let mut y_onehot = vec![0.0f32; batch * 10];
    for (s, &l) in labels.iter().enumerate() {
        y_onehot[s * 10 + l as usize] = 1.0;
    }

    // mlp_grad artifact vs native backward.
    let grad_art = rt.artifact("mlp_grad").unwrap();
    let outs = grad_art.call(&[&theta, &x, &y_onehot]).unwrap();
    let mut scratch = MlpScratch::new(&dims, batch);
    let mut grad_native = vec![0.0f32; d];
    forward(&dims, &theta, &x, &mut scratch);
    let _ = backward(&dims, &theta, &x, &labels, &mut scratch, &mut grad_native);
    let mut max_err = 0.0f32;
    for i in 0..d {
        max_err = max_err.max((outs[0][i] - grad_native[i]).abs());
    }
    assert!(max_err < 1e-3, "grad max err {max_err}");

    // mlp_eval artifact vs native forward logits.
    let eval_art = rt.artifact("mlp_eval").unwrap();
    let eval_batch = eval_art.meta().inputs[1][0];
    let mut xe = vec![0.0f32; eval_batch * dims.input];
    rng.fill_uniform_f32(&mut xe);
    let outs = eval_art.call(&[&theta, &xe]).unwrap();
    let mut scratch = MlpScratch::new(&dims, eval_batch);
    forward(&dims, &theta, &xe, &mut scratch);
    // Logit comparison through a fresh forward.
    let logits_native = {
        let mut v = vec![0.0f32; eval_batch * 10];
        // forward stores logits in scratch; re-run to fill.
        forward(&dims, &theta, &xe, &mut scratch);
        v.copy_from_slice(scratch_logits(&scratch, eval_batch));
        v
    };
    let mut max_err = 0.0f32;
    for i in 0..eval_batch * 10 {
        max_err = max_err.max((outs[0][i] - logits_native[i]).abs());
    }
    assert!(max_err < 1e-2, "eval max err {max_err}");
}

// Accessor shim: MlpScratch keeps logits private to the crate; go through
// the public forward-path by reading them via accuracy-equivalent API.
fn scratch_logits(scratch: &qgadmm::model::mlp::MlpScratch, _batch: usize) -> &[f32] {
    scratch.logits()
}
