//! Wire-format invariants across layers: the bitpack payload codec, the
//! framed message codec, and the `CommStats` bit accounting must agree —
//! the serialized bytes never disagree with `Payload::bits()` by more
//! than the fixed frame overhead.

use qgadmm::comm::{wire, CommStats, Message, Payload, SparseMsg};
use qgadmm::quant::{bitpack, QuantizedMsg};
use qgadmm::testing::property;
use qgadmm::util::rng::Rng;

#[test]
fn bitpack_roundtrip_random_bits_and_levels() {
    // Random width 1..=16, random length, random in-range levels:
    // pack ∘ unpack is the identity and the byte length is exactly
    // ⌈b·d/8⌉.
    property("bitpack roundtrip (integration)", 300, |rng: &mut Rng| {
        let bits = 1 + rng.below(16) as u8;
        let n = rng.below(300);
        let max = 1u64 << bits;
        let levels: Vec<u32> = (0..n).map(|_| rng.below(max as usize) as u32).collect();
        let bytes = bitpack::pack(&levels, bits).unwrap();
        assert_eq!(bytes.len(), (n * bits as usize).div_ceil(8));
        assert_eq!(bitpack::unpack(&bytes, bits, n).unwrap(), levels);
    });
}

#[test]
fn quantized_msg_roundtrip_and_size() {
    property("quantized msg codec", 200, |rng: &mut Rng| {
        let bits = 1 + rng.below(16) as u8;
        let d = rng.below(200);
        let max = 1u64 << bits;
        let msg = QuantizedMsg {
            bits,
            radius: rng.uniform_f32() * 4.0,
            levels: (0..d).map(|_| rng.below(max as usize) as u32).collect(),
        };
        let bytes = msg.encode();
        assert_eq!(bytes.len(), 5 + (bits as usize * d).div_ceil(8));
        assert_eq!(QuantizedMsg::decode(&bytes, d).unwrap(), msg);
    });
}

fn random_sparse(rng: &mut Rng) -> SparseMsg {
    // Occasionally exercise the wide-model (u32-index) path.
    let dims = if rng.below(5) == 0 { 70_000 } else { 1 + rng.below(1_024) };
    let k = rng.below(dims.min(24) + 1);
    let mut picked: Vec<u32> = rng
        .sample_indices(dims, k)
        .into_iter()
        .map(|i| i as u32)
        .collect();
    picked.sort_unstable();
    picked.dedup();
    let values = (0..picked.len())
        .map(|_| rng.uniform_f32() * 4.0 - 2.0)
        .collect();
    SparseMsg {
        dims,
        indices: picked,
        values,
    }
}

fn random_payload(rng: &mut Rng) -> Payload {
    match rng.below(5) {
        0 => Payload::Stop,
        1 => {
            let d = rng.below(128);
            Payload::Full((0..d).map(|_| rng.uniform_f32() * 6.0 - 3.0).collect())
        }
        2 => Payload::Sparse(random_sparse(rng)),
        3 => Payload::Censored,
        _ => {
            let bits = 1 + rng.below(16) as u8;
            let d = rng.below(128);
            let max = 1u64 << bits;
            Payload::Quantized(QuantizedMsg {
                bits,
                radius: rng.uniform_f32(),
                levels: (0..d).map(|_| rng.below(max as usize) as u32).collect(),
            })
        }
    }
}

fn dims_of(p: &Payload) -> usize {
    match p {
        Payload::Stop | Payload::Censored => 0,
        Payload::Full(v) => v.len(),
        Payload::Quantized(q) => q.levels.len(),
        Payload::Sparse(s) => s.dims,
    }
}

#[test]
fn frame_length_matches_payload_bits_plus_header_every_variant() {
    // The accounting drift guard: for every payload variant — including
    // the sparse one — the framed length × 8 equals `Payload::bits()`
    // plus the documented header overhead. Byte-aligned variants (Stop,
    // Censored, Full, Sparse) match *exactly*; the quantized body packs
    // levels to a byte boundary and charges two full 32-bit words for its
    // 5-byte header, so its slack is its documented padding bound.
    property("frame bits = payload bits + overhead", 500, |rng: &mut Rng| {
        let payload = random_payload(rng);
        let frame_bits = 8 * wire::frame_len(&payload) as u64;
        let header_bits = 8 * wire::HEADER_BYTES as u64;
        match &payload {
            Payload::Quantized(q) => {
                // body = 5 bytes + ⌈b·d/8⌉; accounted = b·d + 64.
                let body_bits = 8 * (5 + (q.bits as usize * q.levels.len()).div_ceil(8)) as u64;
                assert_eq!(frame_bits, header_bits + body_bits);
                let slack = frame_bits - payload.bits();
                assert!(slack > 0 && slack <= wire::OVERHEAD_BITS);
            }
            _ => {
                assert_eq!(
                    frame_bits,
                    payload.bits() + header_bits,
                    "byte-aligned variant must cost exactly bits() + header"
                );
            }
        }
    });
}

#[test]
fn sparse_payload_roundtrips_bit_exactly() {
    property("sparse payload codec", 300, |rng: &mut Rng| {
        let sparse = random_sparse(rng);
        let dims = sparse.dims;
        let msg = Message {
            from: rng.below(100),
            round: rng.below(10_000) as u64,
            payload: Payload::Sparse(sparse.clone()),
        };
        let bytes = wire::encode_frame(&msg);
        let (back, used) = wire::decode_frame(&bytes, dims).unwrap();
        assert_eq!(used, bytes.len());
        match back.payload {
            Payload::Sparse(s) => {
                assert_eq!(s, sparse);
                // f32 values survive bit-exactly (to_bits comparison).
                for (a, b) in s.values.iter().zip(&sparse.values) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("variant changed across the wire: {other:?}"),
        }
    });
}

#[test]
fn commstats_bits_vs_wire_bytes_consistency() {
    // Accumulate the paper accounting (CommStats from Payload::bits) and
    // the real framed byte stream side by side: the wire total exceeds
    // the accounted bits by at most OVERHEAD_BITS per frame, and the
    // decoded payloads re-account to exactly the same CommStats.
    property("commstats vs wire", 50, |rng: &mut Rng| {
        let frames = 1 + rng.below(40);
        let mut accounted = CommStats::default();
        let mut reaccounted = CommStats::default();
        let mut wire_bits = 0u64;
        for round in 0..frames {
            let payload = random_payload(rng);
            let dims = dims_of(&payload);
            accounted.record(payload.bits(), 0.0);
            let frame = wire::encode_frame(&Message {
                from: rng.below(64),
                round: round as u64,
                payload,
            });
            wire_bits += 8 * frame.len() as u64;
            let (decoded, used) = wire::decode_frame(&frame, dims).unwrap();
            assert_eq!(used, frame.len());
            reaccounted.record(decoded.payload.bits(), 0.0);
        }
        // The codec is lossless for the accounting: decoding then
        // re-accounting reproduces the sender's ledger bit for bit.
        assert_eq!(accounted.bits, reaccounted.bits);
        assert_eq!(accounted.transmissions, reaccounted.transmissions);
        // And the real bytes are the accounting plus bounded overhead.
        assert!(wire_bits > accounted.bits);
        assert!(
            wire_bits - accounted.bits <= frames as u64 * wire::OVERHEAD_BITS,
            "wire {wire_bits} vs accounted {} over {frames} frames",
            accounted.bits
        );
    });
}

#[test]
fn frame_len_helper_matches_encoder() {
    property("frame_len matches encode_frame", 100, |rng: &mut Rng| {
        let payload = random_payload(rng);
        let frame = wire::encode_frame(&Message {
            from: 0,
            round: 0,
            payload: payload.clone(),
        });
        assert_eq!(frame.len(), wire::frame_len(&payload));
        assert_eq!(
            frame.len(),
            wire::HEADER_BYTES + wire::body_len(&payload)
        );
    });
}
