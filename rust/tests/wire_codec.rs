//! Wire-format invariants across layers: the bitpack payload codec, the
//! framed message codec, and the `CommStats` bit accounting must agree —
//! the serialized bytes never disagree with `Payload::bits()` by more
//! than the fixed frame overhead.

use qgadmm::comm::{wire, CommStats, Message, Payload, SparseMsg};
use qgadmm::quant::{bitpack, QuantizedMsg};
use qgadmm::testing::property;
use qgadmm::util::rng::Rng;

#[test]
fn bitpack_roundtrip_random_bits_and_levels() {
    // Random width 1..=16, random length, random in-range levels:
    // pack ∘ unpack is the identity and the byte length is exactly
    // ⌈b·d/8⌉.
    property("bitpack roundtrip (integration)", 300, |rng: &mut Rng| {
        let bits = 1 + rng.below(16) as u8;
        let n = rng.below(300);
        let max = 1u64 << bits;
        let levels: Vec<u32> = (0..n).map(|_| rng.below(max as usize) as u32).collect();
        let bytes = bitpack::pack(&levels, bits).unwrap();
        assert_eq!(bytes.len(), (n * bits as usize).div_ceil(8));
        assert_eq!(bitpack::unpack(&bytes, bits, n).unwrap(), levels);
    });
}

#[test]
fn quantized_msg_roundtrip_and_size() {
    property("quantized msg codec", 200, |rng: &mut Rng| {
        let bits = 1 + rng.below(16) as u8;
        let d = rng.below(200);
        let max = 1u64 << bits;
        let msg = QuantizedMsg {
            bits,
            radius: rng.uniform_f32() * 4.0,
            levels: (0..d).map(|_| rng.below(max as usize) as u32).collect(),
        };
        let bytes = msg.encode();
        assert_eq!(bytes.len(), 5 + (bits as usize * d).div_ceil(8));
        assert_eq!(QuantizedMsg::decode(&bytes, d).unwrap(), msg);
    });
}

fn random_sparse(rng: &mut Rng) -> SparseMsg {
    // Occasionally exercise the wide-model (u32-index) path.
    let dims = if rng.below(5) == 0 { 70_000 } else { 1 + rng.below(1_024) };
    let k = rng.below(dims.min(24) + 1);
    let mut picked: Vec<u32> = rng
        .sample_indices(dims, k)
        .into_iter()
        .map(|i| i as u32)
        .collect();
    picked.sort_unstable();
    picked.dedup();
    let values = (0..picked.len())
        .map(|_| rng.uniform_f32() * 4.0 - 2.0)
        .collect();
    SparseMsg {
        dims,
        indices: picked,
        values,
    }
}

fn random_payload(rng: &mut Rng) -> Payload {
    match rng.below(5) {
        0 => Payload::Stop,
        1 => {
            let d = rng.below(128);
            Payload::Full((0..d).map(|_| rng.uniform_f32() * 6.0 - 3.0).collect())
        }
        2 => Payload::Sparse(random_sparse(rng)),
        3 => Payload::Censored,
        _ => {
            let bits = 1 + rng.below(16) as u8;
            let d = rng.below(128);
            let max = 1u64 << bits;
            Payload::Quantized(QuantizedMsg {
                bits,
                radius: rng.uniform_f32(),
                levels: (0..d).map(|_| rng.below(max as usize) as u32).collect(),
            })
        }
    }
}

fn dims_of(p: &Payload) -> usize {
    match p {
        Payload::Stop | Payload::Censored => 0,
        Payload::Full(v) => v.len(),
        Payload::Quantized(q) => q.levels.len(),
        Payload::Sparse(s) => s.dims,
    }
}

#[test]
fn frame_length_matches_payload_bits_plus_header_every_variant() {
    // The accounting drift guard: for every payload variant — including
    // the sparse one — the framed length × 8 equals `Payload::bits()`
    // plus the documented header overhead. Byte-aligned variants (Stop,
    // Censored, Full, Sparse) match *exactly*; the quantized body packs
    // levels to a byte boundary and charges two full 32-bit words for its
    // 5-byte header, so its slack is its documented padding bound.
    property("frame bits = payload bits + overhead", 500, |rng: &mut Rng| {
        let payload = random_payload(rng);
        let frame_bits = 8 * wire::frame_len(&payload) as u64;
        let header_bits = 8 * wire::HEADER_BYTES as u64;
        match &payload {
            Payload::Quantized(q) => {
                // body = 5 bytes + ⌈b·d/8⌉; accounted = b·d + 64.
                let body_bits = 8 * (5 + (q.bits as usize * q.levels.len()).div_ceil(8)) as u64;
                assert_eq!(frame_bits, header_bits + body_bits);
                let slack = frame_bits - payload.bits();
                assert!(slack > 0 && slack <= wire::OVERHEAD_BITS);
            }
            _ => {
                assert_eq!(
                    frame_bits,
                    payload.bits() + header_bits,
                    "byte-aligned variant must cost exactly bits() + header"
                );
            }
        }
    });
}

#[test]
fn sparse_payload_roundtrips_bit_exactly() {
    property("sparse payload codec", 300, |rng: &mut Rng| {
        let sparse = random_sparse(rng);
        let dims = sparse.dims;
        let msg = Message {
            from: rng.below(100),
            round: rng.below(10_000) as u64,
            payload: Payload::Sparse(sparse.clone()),
        };
        let bytes = wire::encode_frame(&msg);
        let (back, used) = wire::decode_frame(&bytes, dims).unwrap();
        assert_eq!(used, bytes.len());
        match back.payload {
            Payload::Sparse(s) => {
                assert_eq!(s, sparse);
                // f32 values survive bit-exactly (to_bits comparison).
                for (a, b) in s.values.iter().zip(&sparse.values) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("variant changed across the wire: {other:?}"),
        }
    });
}

#[test]
fn commstats_bits_vs_wire_bytes_consistency() {
    // Accumulate the paper accounting (CommStats from Payload::bits) and
    // the real framed byte stream side by side: the wire total exceeds
    // the accounted bits by at most OVERHEAD_BITS per frame, and the
    // decoded payloads re-account to exactly the same CommStats.
    property("commstats vs wire", 50, |rng: &mut Rng| {
        let frames = 1 + rng.below(40);
        let mut accounted = CommStats::default();
        let mut reaccounted = CommStats::default();
        let mut wire_bits = 0u64;
        for round in 0..frames {
            let payload = random_payload(rng);
            let dims = dims_of(&payload);
            accounted.record(payload.bits(), 0.0);
            let frame = wire::encode_frame(&Message {
                from: rng.below(64),
                round: round as u64,
                payload,
            });
            wire_bits += 8 * frame.len() as u64;
            let (decoded, used) = wire::decode_frame(&frame, dims).unwrap();
            assert_eq!(used, frame.len());
            reaccounted.record(decoded.payload.bits(), 0.0);
        }
        // The codec is lossless for the accounting: decoding then
        // re-accounting reproduces the sender's ledger bit for bit.
        assert_eq!(accounted.bits, reaccounted.bits);
        assert_eq!(accounted.transmissions, reaccounted.transmissions);
        // And the real bytes are the accounting plus bounded overhead.
        assert!(wire_bits > accounted.bits);
        assert!(
            wire_bits - accounted.bits <= frames as u64 * wire::OVERHEAD_BITS,
            "wire {wire_bits} vs accounted {} over {frames} frames",
            accounted.bits
        );
    });
}

/// A random multi-block payload — the v3 frame the flat
/// `random_payload` generator deliberately leaves out (the flat suites
/// above pin per-variant sizes that a `Blocks` arm would complicate).
/// Sub-payloads are the flat variants a `BlockCompressor` actually
/// emits: full, quantized, or a censored marker, each with its own
/// block dimension.
fn random_blocks(rng: &mut Rng) -> Payload {
    let count = 1 + rng.below(4);
    let blocks = (0..count)
        .map(|_| {
            let dims = 1 + rng.below(48);
            let payload = match rng.below(3) {
                0 => Payload::Full((0..dims).map(|_| rng.uniform_f32() * 2.0 - 1.0).collect()),
                1 => Payload::Censored,
                _ => {
                    let bits = 1 + rng.below(8) as u8;
                    let max = 1u64 << bits;
                    Payload::Quantized(QuantizedMsg {
                        bits,
                        radius: rng.uniform_f32(),
                        levels: (0..dims).map(|_| rng.below(max as usize) as u32).collect(),
                    })
                }
            };
            qgadmm::comm::BlockMsg { dims, payload }
        })
        .collect();
    Payload::Blocks(blocks)
}

fn blocks_dims(p: &Payload) -> usize {
    match p {
        Payload::Blocks(blocks) => blocks.iter().map(|b| b.dims).sum(),
        other => dims_of(other),
    }
}

/// Flat or multi-block, weighted toward the interesting variants.
fn robust_payload(rng: &mut Rng) -> Payload {
    if rng.below(3) == 0 {
        random_blocks(rng)
    } else {
        random_payload(rng)
    }
}

#[test]
fn truncation_at_every_offset_is_a_typed_truncated_error() {
    // A receiver reading from a socket sees every possible prefix of a
    // frame; each one must be the typed `Truncated` error (the signal
    // `FrameReader` turns into "wait for more bytes"), never a panic and
    // never a misdecode — for every variant, including v3 Blocks frames.
    property("truncation robustness", 60, |rng: &mut Rng| {
        let payload = robust_payload(rng);
        let dims = blocks_dims(&payload);
        let frame = wire::encode_frame(&Message {
            from: rng.below(32),
            round: rng.below(1_000) as u64,
            payload,
        });
        for cut in 0..frame.len() {
            match wire::decode_frame(&frame[..cut], dims) {
                Err(wire::WireError::Truncated { need, have }) => {
                    assert_eq!(have, cut);
                    assert!(need <= frame.len(), "need {need} beyond the frame");
                }
                other => panic!("prefix {cut}/{}: expected Truncated, got {other:?}", frame.len()),
            }
        }
        // The untruncated frame still decodes.
        let (_, used) = wire::decode_frame(&frame, dims).unwrap();
        assert_eq!(used, frame.len());
    });
}

#[test]
fn corruption_at_every_offset_never_panics_and_body_flips_are_caught() {
    // Flip one byte at every offset: decoding must always return a
    // `Result` (robustness = no panic on any input), and any flip inside
    // the body is guaranteed caught by the CRC (which covers exactly the
    // body). Header flips split by field: magic/version are always
    // rejected; the unprotected from/round/len/crc/tag words may decode,
    // error, or — for len/crc/tag — be caught downstream, so there the
    // contract is only "typed, never a panic".
    property("corruption robustness", 40, |rng: &mut Rng| {
        let payload = robust_payload(rng);
        let dims = blocks_dims(&payload);
        let frame = wire::encode_frame(&Message {
            from: rng.below(32),
            round: rng.below(1_000) as u64,
            payload,
        });
        let mask = 1 + rng.below(255) as u8;
        for at in 0..frame.len() {
            let mut bad = frame.clone();
            bad[at] ^= mask;
            let result = wire::decode_frame(&bad, dims);
            match at {
                0 => assert!(
                    matches!(result, Err(wire::WireError::BadMagic(_))),
                    "magic flip at {at}: {result:?}"
                ),
                1 => assert!(
                    matches!(result, Err(wire::WireError::BadVersion { .. })),
                    "version flip at {at}: {result:?}"
                ),
                _ if at >= wire::HEADER_BYTES => assert!(
                    result.is_err(),
                    "body flip at {at} slipped past the checksum: {result:?}"
                ),
                // from/round (3..15) decode fine with a different sender
                // id; tag/len/crc (2, 15..23) surface as some typed
                // error or an equivalent-length decode — either way the
                // call returned instead of panicking.
                _ => drop(result),
            }
        }
    });
}

#[test]
fn blocks_frame_roundtrips_through_the_codec() {
    property("blocks frame roundtrip", 80, |rng: &mut Rng| {
        let payload = random_blocks(rng);
        let dims = blocks_dims(&payload);
        let msg = Message {
            from: rng.below(32),
            round: rng.below(1_000) as u64,
            payload: payload.clone(),
        };
        let frame = wire::encode_frame(&msg);
        assert_eq!(frame.len(), wire::frame_len(&payload));
        let (back, used) = wire::decode_frame(&frame, dims).unwrap();
        assert_eq!(used, frame.len());
        assert_eq!(back.payload.bits(), payload.bits());
        let (a, b) = match (&back.payload, &payload) {
            (Payload::Blocks(a), Payload::Blocks(b)) => (a, b),
            other => panic!("variant changed across the wire: {other:?}"),
        };
        assert_eq!(a.len(), b.len());
        for (ba, bb) in a.iter().zip(b) {
            assert_eq!(ba.dims, bb.dims);
            // Sub-payloads re-encode to identical bytes — bit-exact
            // without requiring PartialEq on Payload.
            assert_eq!(
                wire::encode_frame(&Message { from: 0, round: 0, payload: ba.payload.clone() }),
                wire::encode_frame(&Message { from: 0, round: 0, payload: bb.payload.clone() }),
            );
        }
    });
}

#[test]
fn frame_len_helper_matches_encoder() {
    property("frame_len matches encode_frame", 100, |rng: &mut Rng| {
        let payload = random_payload(rng);
        let frame = wire::encode_frame(&Message {
            from: 0,
            round: 0,
            payload: payload.clone(),
        });
        assert_eq!(frame.len(), wire::frame_len(&payload));
        assert_eq!(
            frame.len(),
            wire::HEADER_BYTES + wire::body_len(&payload)
        );
    });
}
