//! Tier-1 enforcement of the `qgadmm-tidy` static-analysis pass.
//!
//! Two halves:
//!
//! 1. `repo_is_tidy` runs the full pass over the real tree — so `cargo
//!    test` fails (naming lint and file:line) the moment someone
//!    reintroduces a hash container on a driver path, a raw clock read, a
//!    panicking escape hatch in a protocol module, an unannotated lock
//!    site, or an unsynchronized wire-schema edit.
//! 2. The fixture tests feed the deliberately-dirty files under
//!    `tidy_fixtures/` (excluded from the repo walk, never compiled)
//!    through the scanner with synthetic labels, proving every lint
//!    family both fires and stays quiet where it should.

use std::fs;
use std::path::{Path, PathBuf};

use qgadmm::util::tidy::{
    self, source, wire, DETERMINISM_CLOCK, DETERMINISM_COLLECTIONS, HYGIENE_FEATURES,
    HYGIENE_UNSAFE, LOCK_ORDER, PANIC_SAFETY, TIDY_ALLOW, WIRE_SCHEMA,
};

fn manifest_dir() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn features() -> Vec<String> {
    vec!["default".to_string(), "telemetry".to_string()]
}

/// Scan fixture text under a synthetic repo label, returning lint names.
fn lints(label: &str, text: &str) -> Vec<&'static str> {
    source::check_source(label, text, &features())
        .into_iter()
        .map(|v| v.lint)
        .collect()
}

#[test]
fn repo_is_tidy() {
    let violations = tidy::check_repo(manifest_dir()).expect("scan the repo tree");
    assert!(
        violations.is_empty(),
        "tidy violations in the tree:\n{}",
        violations
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn violations_render_as_file_line_lint() {
    let found = source::check_source(
        "src/coordinator/fixture.rs",
        include_str!("tidy_fixtures/collections_bad.rs"),
        &features(),
    );
    let first = found.first().expect("fixture must fire");
    let rendered = first.to_string();
    assert!(rendered.starts_with("src/coordinator/fixture.rs:"));
    assert!(rendered.contains(&format!("[{DETERMINISM_COLLECTIONS}]")));
}

#[test]
fn collections_fixture_fires_in_scope_only() {
    let bad = include_str!("tidy_fixtures/collections_bad.rs");
    let fired = lints("src/coordinator/fixture.rs", bad);
    assert_eq!(fired, vec![DETERMINISM_COLLECTIONS; 3]);
    // The same text outside the determinism-scoped directories is fine.
    assert!(lints("src/figures/fixture.rs", bad).is_empty());
}

#[test]
fn collections_fixture_passes_with_ordered_maps_and_allows() {
    let ok = include_str!("tidy_fixtures/collections_ok.rs");
    assert!(lints("src/coordinator/fixture.rs", ok).is_empty());
}

#[test]
fn clock_fixture_fires_outside_telemetry_only() {
    let bad = include_str!("tidy_fixtures/clock_bad.rs");
    assert_eq!(lints("src/quant/fixture.rs", bad), vec![DETERMINISM_CLOCK; 3]);
    assert!(lints("src/telemetry/fixture.rs", bad).is_empty());
}

#[test]
fn panic_fixture_fires_in_protocol_files_only() {
    let bad = include_str!("tidy_fixtures/panic_bad.rs");
    assert_eq!(lints("src/comm/wire.rs", bad), vec![PANIC_SAFETY; 2]);
    assert_eq!(lints("src/coordinator/membership.rs", bad), vec![PANIC_SAFETY; 2]);
    assert!(lints("src/comm/other.rs", bad).is_empty());
}

#[test]
fn panic_fixture_passes_with_typed_fallbacks_and_test_exemption() {
    let ok = include_str!("tidy_fixtures/panic_ok.rs");
    assert!(lints("src/net/tcp.rs", ok).is_empty());
}

#[test]
fn lock_fixture_fires_on_missing_malformed_and_inverted_ranks() {
    let bad = include_str!("tidy_fixtures/lock_bad.rs");
    assert_eq!(lints("src/coordinator/threaded.rs", bad), vec![LOCK_ORDER; 3]);
    // Lock discipline only binds in the two threaded/networked modules.
    assert!(lints("src/coordinator/engine.rs", bad).is_empty());
}

#[test]
fn lock_fixture_passes_with_nondecreasing_annotated_ranks() {
    let ok = include_str!("tidy_fixtures/lock_ok.rs");
    assert!(lints("src/net/tcp.rs", ok).is_empty());
}

#[test]
fn malformed_allow_fires_the_unsuppressible_meta_lint() {
    let bad = include_str!("tidy_fixtures/allow_bad.rs");
    assert_eq!(lints("src/util/fixture.rs", bad), vec![TIDY_ALLOW; 3]);
}

#[test]
fn hygiene_fixture_fires_everywhere() {
    let bad = include_str!("tidy_fixtures/hygiene_bad.rs");
    let fired = lints("benches/fixture.rs", bad);
    assert_eq!(fired, vec![HYGIENE_FEATURES, HYGIENE_UNSAFE]);
    let ok = include_str!("tidy_fixtures/hygiene_ok.rs");
    assert!(lints("benches/fixture.rs", ok).is_empty());
}

fn wire_sources() -> (String, String, String) {
    let root = manifest_dir();
    let read = |p: PathBuf| fs::read_to_string(&p).expect("read wire-schema source");
    (
        read(root.join("src").join("comm").join("mod.rs")),
        read(root.join("src").join("comm").join("wire.rs")),
        read(root.join("tests").join("wire_codec.rs")),
    )
}

#[test]
fn wire_schema_is_exhaustive_and_fingerprinted() {
    let (payload, wire_src, codec) = wire_sources();
    let violations = wire::check_wire(&payload, &wire_src, &codec);
    assert!(
        violations.is_empty(),
        "wire-schema violations:\n{}",
        violations
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn deleting_a_decode_arm_fires_wire_schema() {
    let (payload, wire_src, codec) = wire_sources();
    let broken = wire_src.replace("Payload::Sparse(decode_sparse", "sparse_stub(decode_sparse");
    assert_ne!(broken, wire_src, "the Sparse decode arm must exist to delete");
    let violations = wire::check_wire(&payload, &broken, &codec);
    assert_eq!(violations.len(), 1);
    assert_eq!(violations[0].lint, WIRE_SCHEMA);
    assert!(violations[0].message.contains("Payload::Sparse"));
    assert!(violations[0].message.contains("decode"));
}

#[test]
fn schema_edit_without_fingerprint_update_fires_wire_schema() {
    let (payload, wire_src, codec) = wire_sources();
    let bumped = wire_src.replace(
        "pub const WIRE_VERSION: u8 = 3;",
        "pub const WIRE_VERSION: u8 = 4;",
    );
    assert_ne!(bumped, wire_src, "WIRE_VERSION must be where we expect it");
    let violations = wire::check_wire(&payload, &bumped, &codec);
    assert_eq!(violations.len(), 1);
    assert_eq!(violations[0].lint, WIRE_SCHEMA);
    assert!(violations[0].message.contains("bump WIRE_VERSION"));
    assert!(violations[0].file.ends_with("wire.rs"));
    assert!(violations[0].line > 0);
}
