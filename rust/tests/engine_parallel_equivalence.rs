//! Sequential vs parallel engine equivalence — the contract the phase
//! executor rests on (ISSUE 2 acceptance criterion).
//!
//! The parallel engine claims *bit-for-bit* equality with the sequential
//! one: per-position RNGs are forked once at construction, every
//! same-parity position writes disjoint state, and the neighbor context
//! only reads opposite-parity views — so the schedule cannot influence a
//! single bit of θ, θ̂ (views), λ, or the communication accounting. These
//! tests run 50 iterations of a strictly sequential engine (`threads: 1`)
//! against a forced-parallel one (`threads: 4`, scoped threads even at
//! tiny dimensions) and require exact equality — for the quantized and
//! full-precision linreg configs, the d = 2048 diagonal-Gram scale
//! problem, and a reduced-width MLP (Q-SGADMM).

use qgadmm::config::{CompressorConfig, GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::GadmmEngine;
use qgadmm::data::images::{ImageDataset, ImageSpec};
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::mlp::{MlpDims, MlpProblem};
use qgadmm::model::scale::DiagLinRegProblem;
use qgadmm::model::LocalProblem;
use qgadmm::net::topology::Topology;

/// Iterate both engines `iters` times and assert every piece of externally
/// visible state matches exactly.
fn assert_equal_runs<P: LocalProblem, Q: LocalProblem>(
    mut seq: GadmmEngine<P>,
    mut par: GadmmEngine<Q>,
    iters: usize,
    label: &str,
) {
    let n = seq.workers();
    assert_eq!(n, par.workers());
    for k in 0..iters {
        let rs = seq.iterate();
        let rp = par.iterate();
        assert_eq!(rs.primal_sq, rp.primal_sq, "{label}: residual @ iter {k}");
        assert_eq!(rs.dual_sq, rp.dual_sq, "{label}: dual residual @ iter {k}");
    }
    for p in 0..n {
        assert_eq!(seq.theta_at(p), par.theta_at(p), "{label}: theta @ {p}");
        assert_eq!(seq.view_at(p), par.view_at(p), "{label}: view @ {p}");
    }
    for l in 0..n - 1 {
        assert_eq!(seq.lambda_at(l), par.lambda_at(l), "{label}: lambda @ {l}");
    }
    assert_eq!(seq.comm().bits, par.comm().bits, "{label}: comm bits");
    assert_eq!(
        seq.comm().transmissions,
        par.comm().transmissions,
        "{label}: transmissions"
    );
    assert_eq!(
        seq.comm().censored,
        par.comm().censored,
        "{label}: censored tally"
    );
}

fn linreg_engine_with(
    workers: usize,
    compressor: CompressorConfig,
    threads: usize,
) -> GadmmEngine<LinRegProblem> {
    let spec = LinRegSpec {
        samples: 2_000,
        ..LinRegSpec::default()
    };
    let data = LinRegDataset::synthesize(&spec, 21);
    let partition = Partition::contiguous(data.samples(), workers);
    let problem = LinRegProblem::new(&data, &partition, 1600.0);
    let cfg = GadmmConfig {
        workers,
        rho: 1600.0,
        dual_step: 1.0,
        compressor,
        threads,
    };
    GadmmEngine::new(cfg, problem, Topology::line(workers), 99)
}

fn linreg_engine(
    workers: usize,
    quant: Option<QuantConfig>,
    threads: usize,
) -> GadmmEngine<LinRegProblem> {
    linreg_engine_with(workers, quant.into(), threads)
}

#[test]
fn quantized_linreg_parallel_matches_sequential() {
    let seq = linreg_engine(7, Some(QuantConfig::default()), 1);
    let par = linreg_engine(7, Some(QuantConfig::default()), 4);
    assert_equal_runs(seq, par, 50, "Q-GADMM linreg");
}

#[test]
fn full_precision_linreg_parallel_matches_sequential() {
    let seq = linreg_engine(7, None, 1);
    let par = linreg_engine(7, None, 4);
    assert_equal_runs(seq, par, 50, "GADMM linreg");
}

#[test]
fn adaptive_bits_parallel_matches_sequential() {
    // The eq. (11) adaptive rule carries (prev_bits, prev_radius) state in
    // each quantizer across iterations — per-position state the executor
    // must move in and out of jobs intact.
    let quant = Some(QuantConfig {
        bits: 2,
        adaptive: true,
        max_bits: 8,
    });
    let seq = linreg_engine(6, quant, 1);
    let par = linreg_engine(6, quant, 4);
    assert_equal_runs(seq, par, 50, "adaptive Q-GADMM");
}

#[test]
fn censored_parallel_matches_sequential() {
    // Censoring keeps per-position threshold state (call count) inside
    // the compressor; the executor must move it through jobs intact and
    // charge censored rounds identically in both schedules.
    let comp = CompressorConfig::Censored {
        quant: QuantConfig::default(),
        tau0: 0.05,
        decay: 0.995,
    };
    let seq = linreg_engine_with(6, comp.clone(), 1);
    let par = linreg_engine_with(6, comp, 4);
    assert_equal_runs(seq, par, 50, "censored Q-GADMM");
}

#[test]
fn topk_parallel_matches_sequential() {
    let comp = CompressorConfig::TopK { frac: 0.4 };
    let seq = linreg_engine_with(6, comp.clone(), 1);
    let par = linreg_engine_with(6, comp, 4);
    assert_equal_runs(seq, par, 50, "top-k GADMM");
}

#[test]
fn scale_problem_parallel_matches_sequential() {
    let make = |threads: usize| {
        let cfg = GadmmConfig {
            workers: 16,
            rho: 4.0,
            dual_step: 1.0,
            compressor: CompressorConfig::default(),
            threads,
        };
        let problem = DiagLinRegProblem::synthesize(2_048, 16, 5);
        GadmmEngine::new(cfg, problem, Topology::line(16), 12)
    };
    assert_equal_runs(make(1), make(4), 50, "diag-Gram scale");
}

#[test]
fn mlp_parallel_matches_sequential() {
    // Reduced-width MLP (same input/classes, thin hidden layers) keeps the
    // runtime test-sized; worker-private RNG + Adam state is exactly what
    // this exercises.
    let dims = MlpDims {
        hidden1: 8,
        hidden2: 4,
        ..MlpDims::paper()
    };
    let spec = ImageSpec {
        train: 400,
        test: 50,
        ..ImageSpec::default()
    };
    let data = ImageDataset::synthesize(&spec, 7);
    let make = |threads: usize| {
        let partition = Partition::contiguous(data.train_len(), 4);
        let problem = MlpProblem::with_hyper(&data, &partition, dims, 20, 5, 0.001, 31);
        let init = problem.initial_theta(8);
        let cfg = GadmmConfig {
            workers: 4,
            rho: 20.0,
            dual_step: 0.01,
            compressor: CompressorConfig::Stochastic(QuantConfig {
                bits: 8,
                ..QuantConfig::default()
            }),
            threads,
        };
        let mut engine = GadmmEngine::new(cfg, problem, Topology::line(4), 42);
        engine.set_initial_theta(&init);
        engine
    };
    assert_equal_runs(make(1), make(4), 15, "Q-SGADMM mlp");
}
