//! The discrete-event simulator's two hard guarantees:
//!
//! 1. **Determinism** — the same seed + `SimConfig` yields bit-identical
//!    event traces and metric curves across runs, including under loss,
//!    bursts, stragglers, and dropouts.
//! 2. **Engine equivalence** — with loss 0 and zero latency
//!    (`SimConfig::ideal()`), the simulated runtime reproduces
//!    `GadmmEngine`'s per-iteration models bit-for-bit (the
//!    `threaded_equivalence` pattern, extended to the simulator).

use qgadmm::config::{Dropout, GadmmConfig, QuantConfig, SimConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::coordinator::simulated::SimulatedGadmm;
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::net::geometry::collinear;
use qgadmm::net::topology::Topology;

fn world(workers: usize) -> (LinRegDataset, Partition) {
    let spec = LinRegSpec {
        samples: 1_400,
        ..LinRegSpec::default()
    };
    let data = LinRegDataset::synthesize(&spec, 71);
    let partition = Partition::contiguous(data.samples(), workers);
    (data, partition)
}

fn build_sim(
    quant: Option<QuantConfig>,
    sim_cfg: SimConfig,
    workers: usize,
    seed: u64,
) -> (LinRegDataset, SimulatedGadmm<LinRegProblem>) {
    let (data, partition) = world(workers);
    let rho = 1600.0f32;
    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor: quant.into(),
        threads: 0,
    };
    let problem = LinRegProblem::new(&data, &partition, rho);
    let sim = SimulatedGadmm::new(
        cfg,
        sim_cfg,
        problem,
        Topology::line(workers),
        collinear(workers, 40.0),
        seed,
    );
    (data, sim)
}

/// Same seed + config ⇒ bit-identical traces and curves.
fn assert_two_runs_identical(sim_cfg: SimConfig, quant: Option<QuantConfig>, iters: u64) {
    let opts = RunOptions {
        iterations: iters,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let run = || {
        let (_, mut sim) = build_sim(quant, sim_cfg.clone(), 6, 2024);
        let report = sim.run(&opts, |s| s.global_objective());
        report
    };
    let a = run();
    let b = run();

    let (ea, eb) = (a.sim_ext(), b.sim_ext());
    assert_eq!(ea.trace, eb.trace, "event traces diverged");
    assert!(!ea.trace.is_empty(), "trace recording must be on for this test");
    assert_eq!(a.iterations_run, b.iterations_run);
    assert_eq!(a.comm.bits, b.comm.bits);
    assert_eq!(ea.net, eb.net);
    assert_eq!(ea.sim_secs.to_bits(), eb.sim_secs.to_bits());
    assert_eq!(a.recorder.points.len(), b.recorder.points.len());
    for (pa, pb) in a.recorder.points.iter().zip(&b.recorder.points) {
        assert_eq!(pa.iteration, pb.iteration);
        assert_eq!(pa.bits, pb.bits);
        assert_eq!(pa.comm_rounds, pb.comm_rounds);
        assert_eq!(
            pa.value.to_bits(),
            pb.value.to_bits(),
            "metric diverged at iteration {}",
            pa.iteration
        );
        assert_eq!(
            pa.compute_secs.to_bits(),
            pb.compute_secs.to_bits(),
            "virtual clock diverged at iteration {}",
            pa.iteration
        );
    }
}

#[test]
fn deterministic_under_iid_loss() {
    let mut s = SimConfig::default();
    s.loss = 0.15;
    s.record_trace = true;
    assert_two_runs_identical(s, Some(QuantConfig::default()), 50);
}

#[test]
fn deterministic_under_bursts_stragglers_and_dropouts() {
    let mut s = SimConfig::default();
    s.loss = 0.05;
    s.burst = Some(qgadmm::config::BurstParams::default());
    s.stragglers = 2;
    s.straggler_factor = 6.0;
    s.compute_jitter = 0.8;
    s.dropouts = vec![Dropout {
        worker: 4,
        at_iteration: 20,
    }];
    s.record_trace = true;
    assert_two_runs_identical(s, Some(QuantConfig::default()), 60);
}

/// The `threaded_equivalence` pattern, extended: ideal network ⇒ the
/// simulator is the deterministic engine, bit for bit.
fn run_equivalence_pair(quant: Option<QuantConfig>, workers: usize, iters: u64, seed: u64) {
    let (data, partition) = world(workers);
    let rho = 1600.0f32;
    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor: quant.into(),
        threads: 0,
    };
    let opts = RunOptions {
        iterations: iters,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };

    // Deterministic engine.
    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut engine = GadmmEngine::new(cfg.clone(), problem, Topology::line(workers), seed);
    let eng_report = engine.run(&opts, |e| e.global_objective());

    // Simulated runtime over the ideal network.
    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut sim = SimulatedGadmm::new(
        cfg,
        SimConfig::ideal(),
        problem,
        Topology::line(workers),
        collinear(workers, 40.0),
        seed,
    );
    let sim_report = sim.run(&opts, |s| s.global_objective());

    // Bit-for-bit: per-iteration objectives, final models, views, comm.
    assert_eq!(
        eng_report.recorder.points.len(),
        sim_report.recorder.points.len()
    );
    for (a, b) in eng_report
        .recorder
        .points
        .iter()
        .zip(&sim_report.recorder.points)
    {
        assert_eq!(
            a.value.to_bits(),
            b.value.to_bits(),
            "objective diverged at iteration {}",
            a.iteration
        );
        assert_eq!(a.bits, b.bits, "bit accounting diverged at {}", a.iteration);
        assert_eq!(a.comm_rounds, b.comm_rounds);
    }
    for p in 0..workers {
        assert_eq!(
            engine.theta_at(p),
            sim.theta_of(p),
            "theta diverged at position {p}"
        );
        assert_eq!(
            engine.view_at(p),
            sim.view_of(p),
            "view diverged at position {p}"
        );
    }
    assert_eq!(engine.comm().bits, sim.comm().bits);
    assert_eq!(engine.comm().transmissions, sim.comm().transmissions);
    // Ideal network: nothing retransmitted, nothing stale, clock at zero.
    assert_eq!(sim.net_stats().retransmissions, 0);
    assert_eq!(sim.net_stats().abandoned, 0);
    assert_eq!(sim.stale_rounds(), 0);
    assert_eq!(sim.now_secs(), 0.0);
}

#[test]
fn ideal_network_quantized_matches_engine() {
    run_equivalence_pair(Some(QuantConfig::default()), 6, 60, 2024);
}

#[test]
fn ideal_network_full_precision_matches_engine() {
    run_equivalence_pair(None, 5, 60, 7);
}

#[test]
fn ideal_network_odd_workers_higher_bits_matches_engine() {
    run_equivalence_pair(
        Some(QuantConfig {
            bits: 4,
            ..QuantConfig::default()
        }),
        7,
        40,
        99,
    );
}

#[test]
fn loss_changes_trajectories_but_not_legality() {
    // Sanity for the fault path: a lossy run must *diverge* from the
    // lossless one (stale mirrors really happen) while staying finite.
    let mut lossy_cfg = SimConfig::ideal();
    lossy_cfg.loss = 0.5;
    lossy_cfg.max_attempts = 1; // every loss is an abandoned frame
    let (_, mut ideal) = build_sim(Some(QuantConfig::default()), SimConfig::ideal(), 6, 11);
    let (_, mut lossy) = build_sim(Some(QuantConfig::default()), lossy_cfg, 6, 11);
    for _ in 0..30 {
        assert!(ideal.iterate());
        assert!(lossy.iterate());
    }
    assert!(lossy.stale_rounds() > 0, "p=0.5 cap=1 must drop frames");
    let mut any_diff = false;
    for p in 0..6 {
        if ideal.theta_of(p) != lossy.theta_of(p) {
            any_diff = true;
        }
        assert!(lossy.theta_of(p).iter().all(|x| x.is_finite()));
    }
    assert!(any_diff, "loss must perturb the trajectory");
}
