//! Layer-wise (per-block) compression and adaptive ρ, end to end through
//! the Session API:
//!
//! 1. **Flat-path pin** — the single-block composition `layers:all=…`
//!    goes through the genuine per-block machinery (Blocks compressor,
//!    v3 frames) yet must reproduce the pre-refactor flat path
//!    bit-for-bit, pinned for 50 iterations on the chain and the ring.
//! 2. **Cross-driver equivalence** — a genuinely multi-block MLP spec
//!    runs bit-for-bit identically on the engine, the threaded runtime,
//!    and the simulator (ideal network), like every other scheme.
//! 3. **Bit accounting** — a layered broadcast's bits are exactly the
//!    sum of the per-block payloads.
//! 4. **Adaptive ρ** — the residual-balancing policy is driver-uniform:
//!    same θ, same bits, same residual trace on all three drivers.

use qgadmm::config::{CompressorConfig, QuantConfig, SimConfig};
use qgadmm::coordinator::engine::RunOptions;
use qgadmm::coordinator::residuals::RhoPolicy;
use qgadmm::net::topology::TopologyKind;
use qgadmm::runtime::session::{DriverKind, ProblemKind, Session};

fn layers(spec: &str) -> CompressorConfig {
    CompressorConfig::parse(spec, QuantConfig::default()).unwrap()
}

/// The multi-block exercise: one scheme per MLP weight block.
const MLP_SPEC: &str = "layers:w1=stochastic@4,w2=stochastic@8,w3=full";

#[test]
fn single_block_layers_matches_flat_for_50_iterations_on_chain_and_ring() {
    let opts = RunOptions {
        iterations: 50,
        eval_every: 1,
        ..RunOptions::default()
    };
    for topology in [TopologyKind::Line, TopologyKind::Ring] {
        let run = |comp: CompressorConfig| {
            Session::new(ProblemKind::LinReg)
                .quick(true)
                .workers(6)
                .seed(17)
                .topology(topology)
                .compressor(comp)
                .options(opts.clone())
                .run()
                .unwrap_or_else(|e| panic!("{}: {e}", topology.name()))
        };
        let flat = run(CompressorConfig::Stochastic(QuantConfig::default()));
        let layered = run(layers("layers:all=stochastic@2"));
        let name = topology.name();
        assert_eq!(flat.iterations_run, layered.iterations_run, "{name}");
        assert_eq!(flat.comm.bits, layered.comm.bits, "{name}: bits diverged");
        assert_eq!(
            flat.comm.transmissions, layered.comm.transmissions,
            "{name}: transmissions diverged"
        );
        assert_eq!(flat.thetas, layered.thetas, "{name}: final models diverged");
        assert_eq!(flat.recorder.points.len(), layered.recorder.points.len());
        for (a, b) in flat.recorder.points.iter().zip(&layered.recorder.points) {
            assert_eq!(
                a.value.to_bits(),
                b.value.to_bits(),
                "{name}: metric diverged at iteration {}",
                a.iteration
            );
            assert_eq!(a.bits, b.bits, "{name}: bit curve diverged at {}", a.iteration);
        }
    }
}

#[test]
fn layered_mlp_agrees_across_drivers() {
    let opts = RunOptions {
        iterations: 2,
        eval_every: 1,
        ..RunOptions::default()
    };
    let run = |driver| {
        let mut s = Session::new(ProblemKind::Mlp)
            .quick(true)
            .workers(4)
            .seed(41)
            .driver(driver)
            .compressor(layers(MLP_SPEC))
            .options(opts.clone());
        if driver == DriverKind::Sim {
            s = s.sim_config(SimConfig::ideal());
        }
        s.run().unwrap_or_else(|e| panic!("{driver:?} failed: {e}"))
    };
    let engine = run(DriverKind::Engine);
    let threaded = run(DriverKind::Threaded);
    let sim = run(DriverKind::Sim);
    assert_eq!(engine.comm.bits, threaded.comm.bits, "engine vs threaded bits");
    assert_eq!(engine.comm.bits, sim.comm.bits, "engine vs sim bits");
    assert_eq!(engine.thetas, threaded.thetas, "engine vs threaded models");
    assert_eq!(engine.thetas, sim.thetas, "engine vs sim models");
    for (other, label) in [(&threaded, "threaded"), (&sim, "sim")] {
        assert_eq!(engine.recorder.points.len(), other.recorder.points.len(), "{label}");
        for (a, b) in engine.recorder.points.iter().zip(&other.recorder.points) {
            assert_eq!(
                a.value.to_bits(),
                b.value.to_bits(),
                "accuracy diverged from {label} at iteration {}",
                a.iteration
            );
        }
    }
}

#[test]
fn layered_mlp_bits_are_the_sum_of_per_block_payloads() {
    let opts = RunOptions {
        iterations: 1,
        eval_every: 1,
        ..RunOptions::default()
    };
    let layered = Session::new(ProblemKind::Mlp)
        .quick(true)
        .workers(4)
        .seed(41)
        .compressor(layers(MLP_SPEC))
        .options(opts)
        .run()
        .unwrap();
    // Quantized blocks pay `bits·len + 64` (range header), full-precision
    // blocks `32·len` — per broadcast, summed over the three MLP weight
    // blocks (784·128, 128·64, 64·10).
    let w1 = 4 * (784 * 128) + 64;
    let w2 = 8 * (128 * 64) + 64;
    let w3 = 32 * (64 * 10);
    let per_broadcast = (w1 + w2 + w3) as u64;
    assert_eq!(layered.comm.bits, 4 * per_broadcast);
    // The headline economics: the layered spec undercuts the uniform
    // 8-bit default per broadcast.
    let uniform: u64 = 8 * 109_184 + 64;
    assert!(per_broadcast < uniform);
}

#[test]
fn adaptive_rho_is_driver_uniform_through_the_session() {
    // μ = 1 makes the balancing rule fire whenever the primal and dual
    // residuals differ at all, so ρ genuinely moves during the run.
    let policy = RhoPolicy::ResidualBalance {
        mu: 1.0,
        tau_incr: 2.0,
        tau_decr: 2.0,
    };
    let opts = RunOptions {
        iterations: 30,
        eval_every: 1,
        rho_policy: policy,
        ..RunOptions::default()
    };
    let run = |driver| {
        let mut s = Session::new(ProblemKind::LinReg)
            .quick(true)
            .workers(6)
            .seed(23)
            .driver(driver)
            .options(opts.clone());
        if driver == DriverKind::Sim {
            s = s.sim_config(SimConfig::ideal());
        }
        s.run().unwrap_or_else(|e| panic!("{driver:?} failed: {e}"))
    };
    let engine = run(DriverKind::Engine);
    let threaded = run(DriverKind::Threaded);
    let sim = run(DriverKind::Sim);
    assert_eq!(engine.thetas, threaded.thetas, "engine vs threaded models");
    assert_eq!(engine.thetas, sim.thetas, "engine vs sim models");
    assert_eq!(engine.comm.bits, threaded.comm.bits);
    assert_eq!(engine.comm.bits, sim.comm.bits);
    assert_eq!(engine.residuals.len(), 30);
    assert_eq!(threaded.residuals.len(), 30);
    assert_eq!(sim.residuals.len(), 30);
    for (other, label) in [(&threaded, "threaded"), (&sim, "sim")] {
        for (a, b) in engine.residuals.iter().zip(&other.residuals) {
            assert_eq!(a.iteration, b.iteration, "{label}");
            assert_eq!(
                a.primal_sq.to_bits(),
                b.primal_sq.to_bits(),
                "{label}: primal residual diverged at iteration {}",
                a.iteration
            );
            assert_eq!(
                a.dual_sq.to_bits(),
                b.dual_sq.to_bits(),
                "{label}: dual residual diverged at iteration {}",
                a.iteration
            );
        }
    }
}
