//! The `Compressor`-trait redesign's regression harness.
//!
//! An independent reference implementation of the *pre-redesign* broadcast
//! path — raw `StochasticQuantizer::quantize_into` calls (or plain copies,
//! full precision) with hand-rolled `payload_bits` accounting, no
//! `Compressor` trait anywhere — runs the same head/tail schedule as the
//! engine over the same `Topology`, and must match the trait-driven engine
//! **bit for bit** over 50 iterations:
//!
//! * `compressor = stochastic` vs the raw quantizer path, on the chain and
//!   on a ring (quantized), pinning that enum dispatch + the trait adapter
//!   changed nothing;
//! * `compressor = full` vs the legacy full-precision baseline trajectory
//!   (view copies, `32·d` bits).

use qgadmm::config::{CompressorConfig, GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::GadmmEngine;
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::{LinkBuf, LocalProblem, NeighborLink};
use qgadmm::net::topology::Topology;
use qgadmm::quant::{self, BitPolicy, StochasticQuantizer};
use qgadmm::util::rng::Rng;

fn world(workers: usize) -> (LinRegDataset, Partition) {
    let spec = LinRegSpec {
        samples: 1_400,
        ..LinRegSpec::default()
    };
    let data = LinRegDataset::synthesize(&spec, 71);
    let partition = Partition::contiguous(data.samples(), workers);
    (data, partition)
}

/// The pre-redesign broadcast path, written directly against
/// `StochasticQuantizer` (no `Compressor` in sight), over any bipartite
/// topology. Solves go through the same `LinRegProblem` the engine uses —
/// only the *broadcast* layer differs, which is exactly what the pin
/// isolates.
struct RawReference {
    problem: LinRegProblem,
    topo: Topology,
    theta: Vec<Vec<f32>>,
    view: Vec<Vec<f32>>,
    lambda: Vec<Vec<f32>>,
    quantizers: Option<Vec<StochasticQuantizer>>,
    rngs: Vec<Rng>,
    rho: f32,
    bits: u64,
    transmissions: u64,
}

impl RawReference {
    fn new(
        data: &LinRegDataset,
        partition: &Partition,
        topo: Topology,
        rho: f32,
        quant: bool,
        seed: u64,
    ) -> RawReference {
        let n = topo.len();
        let problem = LinRegProblem::new(data, partition, rho);
        let d = problem.dims();
        let mut root = Rng::seed_from_u64(seed);
        let rngs = (0..n).map(|p| root.fork(p as u64)).collect();
        let quantizers = quant.then(|| {
            (0..n)
                .map(|_| StochasticQuantizer::new(d, BitPolicy::Fixed(2)))
                .collect()
        });
        let edge_count = topo.edge_count();
        RawReference {
            problem,
            theta: vec![vec![0.0; d]; n],
            view: vec![vec![0.0; d]; n],
            lambda: vec![vec![0.0; d]; edge_count],
            quantizers,
            rngs,
            rho,
            bits: 0,
            transmissions: 0,
            topo,
        }
    }

    fn step_position(&mut self, p: usize) {
        let worker = self.topo.worker_at(p);
        let d = self.theta[p].len();
        let mut buf = LinkBuf::new();
        for e in self.topo.incident(p) {
            buf.push(NeighborLink {
                sign: e.sign,
                lambda: self.lambda[e.edge].as_slice(),
                theta: self.view[e.peer].as_slice(),
            });
        }
        let ctx = buf.ctx(self.rho);
        let mut out = std::mem::take(&mut self.theta[p]);
        self.problem.solve(worker, &ctx, &mut out);
        self.theta[p] = out;

        // The pre-redesign broadcast: quantize_into straight into the
        // view, or copy for the full-precision baseline.
        match self.quantizers.as_mut() {
            Some(qs) => {
                let (bits, _radius) =
                    qs[p].quantize_into(&self.theta[p], &mut self.rngs[p], &mut self.view[p]);
                self.bits += quant::payload_bits(bits, d);
            }
            None => {
                self.view[p].copy_from_slice(&self.theta[p]);
                self.bits += 32 * d as u64;
            }
        }
        self.transmissions += 1;
    }

    fn iterate(&mut self) {
        for phase in 0..2 {
            for p in 0..self.topo.len() {
                if self.topo.is_head(p) == (phase == 0) {
                    self.step_position(p);
                }
            }
        }
        let step = self.rho; // dual_step = 1.0
        for (e, &(u, v)) in self.topo.edges().iter().enumerate() {
            for j in 0..self.lambda[e].len() {
                let delta = step * (self.view[u][j] - self.view[v][j]);
                self.lambda[e][j] += delta;
            }
        }
    }
}

fn assert_trait_matches_raw(topo: Topology, quant: bool, iters: usize, seed: u64) {
    let workers = topo.len();
    let (data, partition) = world(workers);
    let rho = 1600.0f32;

    let mut reference =
        RawReference::new(&data, &partition, topo.clone(), rho, quant, seed);
    for _ in 0..iters {
        reference.iterate();
    }

    let compressor = if quant {
        CompressorConfig::Stochastic(QuantConfig::default())
    } else {
        CompressorConfig::FullPrecision
    };
    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor,
        threads: 1,
    };
    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut engine = GadmmEngine::new(cfg, problem, topo.clone(), seed);
    for _ in 0..iters {
        engine.iterate();
    }

    for p in 0..workers {
        assert_eq!(
            engine.theta_at(p),
            reference.theta[p].as_slice(),
            "θ diverged from the raw-quantizer path at position {p}"
        );
        assert_eq!(
            engine.view_at(p),
            reference.view[p].as_slice(),
            "θ̂ diverged from the raw-quantizer path at position {p}"
        );
    }
    for l in 0..topo.edge_count() {
        assert_eq!(
            engine.lambda_at(l),
            reference.lambda[l].as_slice(),
            "λ diverged from the raw-quantizer path on link {l}"
        );
    }
    assert_eq!(engine.comm().bits, reference.bits, "bit accounting diverged");
    assert_eq!(
        engine.comm().transmissions,
        reference.transmissions,
        "transmission accounting diverged"
    );
    assert_eq!(engine.comm().censored, 0, "stochastic/full never censor");
}

#[test]
fn stochastic_via_trait_pins_chain_trajectory() {
    assert_trait_matches_raw(Topology::line(6), true, 50, 2024);
}

#[test]
fn stochastic_via_trait_pins_ring_trajectory() {
    assert_trait_matches_raw(Topology::ring(6).unwrap(), true, 50, 31);
}

#[test]
fn full_precision_via_trait_pins_chain_trajectory() {
    assert_trait_matches_raw(Topology::line(5), false, 50, 7);
}

#[test]
fn full_precision_via_trait_pins_ring_trajectory() {
    assert_trait_matches_raw(Topology::ring(4).unwrap(), false, 50, 13);
}
