//! Observer event-stream contracts under the non-default compression
//! schemes, across all three drivers:
//!
//! * censored rounds must *appear* in the broadcast stream (as
//!   `censored: true, bits: 0` events), not vanish — downstream
//!   bits-vs-accuracy accounting depends on seeing every round;
//! * top-k rounds carry their sparsified bit cost in the same canonical
//!   order (heads ascending, then tails ascending);
//! * an observer with `wants_broadcasts() == false` must never receive —
//!   or pay for — a broadcast event on any driver.

use qgadmm::coordinator::engine::RunOptions;
use qgadmm::prelude::*;

const WORKERS: usize = 6;
const ITERS: u64 = 4;

#[derive(Default)]
struct BroadcastLog {
    events: Vec<BroadcastEvent>,
}

impl Observer for BroadcastLog {
    fn on_broadcast(&mut self, event: &BroadcastEvent) {
        self.events.push(*event);
    }

    fn wants_broadcasts(&self) -> bool {
        true
    }
}

/// An observer that did not opt into broadcasts and treats receiving one
/// as a contract violation.
struct RefusesBroadcasts;

impl Observer for RefusesBroadcasts {
    fn on_broadcast(&mut self, event: &BroadcastEvent) {
        panic!(
            "observer with wants_broadcasts == false received {event:?}; \
             the driver must not construct broadcast events for it"
        );
    }
}

fn run_with(
    kind: DriverKind,
    comp: CompressorConfig,
    observer: &mut dyn Observer,
) -> RunSummary {
    Session::new(ProblemKind::LinReg)
        .quick(true)
        .workers(WORKERS)
        .driver(kind)
        .compressor(comp)
        .seed(4)
        .sim_config(SimConfig::ideal())
        .options(RunOptions {
            iterations: ITERS,
            eval_every: ITERS,
            stop_below: None,
            stop_above: None,
            ..RunOptions::default()
        })
        .run_observed(observer)
        .unwrap_or_else(|e| panic!("{} failed: {e}", kind.name()))
}

/// Line topology over identity-ordered workers: heads are the even
/// positions, so the canonical per-iteration broadcast order is
/// 0, 2, 4, then 1, 3, 5.
fn assert_canonical_order(kind: DriverKind, events: &[BroadcastEvent]) {
    assert_eq!(
        events.len(),
        WORKERS * ITERS as usize,
        "{}: one event per worker per iteration",
        kind.name()
    );
    for (i, chunk) in events.chunks(WORKERS).enumerate() {
        let k = (i + 1) as u64;
        assert!(
            chunk.iter().all(|e| e.iteration == k),
            "{}: iteration {k} events interleaved",
            kind.name()
        );
        let order: Vec<usize> = chunk.iter().map(|e| e.worker).collect();
        assert_eq!(
            order,
            [0, 2, 4, 1, 3, 5],
            "{}: heads-then-tails order broken at iteration {k}",
            kind.name()
        );
    }
}

#[test]
fn censored_rounds_surface_as_events_on_every_driver() {
    // τ₀ huge with no decay: every round is censored on every worker.
    let comp = CompressorConfig::Censored {
        quant: QuantConfig::default(),
        tau0: 1e30,
        decay: 1.0,
    };
    let mut streams = Vec::new();
    for kind in [DriverKind::Engine, DriverKind::Threaded, DriverKind::Sim] {
        let mut obs = BroadcastLog::default();
        let summary = run_with(kind, comp.clone(), &mut obs);
        assert_eq!(summary.comm.censored, WORKERS as u64 * ITERS);
        assert_eq!(summary.comm.bits, 0);
        assert_canonical_order(kind, &obs.events);
        assert!(
            obs.events.iter().all(|e| e.censored && e.bits == 0),
            "{}: censored events must carry censored=true, bits=0",
            kind.name()
        );
        streams.push(obs.events);
    }
    assert_eq!(streams[0], streams[1], "engine vs threaded censored streams");
    assert_eq!(streams[0], streams[2], "engine vs sim censored streams");
}

#[test]
fn topk_rounds_stream_in_canonical_order_on_every_driver() {
    let comp = CompressorConfig::TopK { frac: 0.5 };
    let mut streams = Vec::new();
    for kind in [DriverKind::Engine, DriverKind::Threaded, DriverKind::Sim] {
        let mut obs = BroadcastLog::default();
        let summary = run_with(kind, comp.clone(), &mut obs);
        assert_canonical_order(kind, &obs.events);
        assert!(
            obs.events.iter().all(|e| !e.censored && e.bits > 0),
            "{}: top-k rounds always transmit",
            kind.name()
        );
        let per_event_bits = obs.events[0].bits;
        assert!(
            obs.events.iter().all(|e| e.bits == per_event_bits),
            "{}: top-k bit cost is shape-determined, so constant",
            kind.name()
        );
        assert_eq!(
            summary.comm.bits,
            per_event_bits * WORKERS as u64 * ITERS,
            "{}: summary bits must equal the streamed events' sum",
            kind.name()
        );
        streams.push(obs.events);
    }
    assert_eq!(streams[0], streams[1], "engine vs threaded top-k streams");
    assert_eq!(streams[0], streams[2], "engine vs sim top-k streams");
}

#[test]
fn uninterested_observers_never_receive_broadcasts() {
    // Regression for the simulated driver in particular: BroadcastEvent
    // construction must be skipped entirely when the observer opted out,
    // not constructed-then-dropped. The panicking observer proves no
    // event reaches `on_broadcast` on any driver.
    for kind in [DriverKind::Engine, DriverKind::Threaded, DriverKind::Sim] {
        let summary = run_with(
            kind,
            CompressorConfig::Stochastic(QuantConfig::default()),
            &mut RefusesBroadcasts,
        );
        assert_eq!(summary.iterations_run, ITERS, "{}", kind.name());
    }
}
