//! The new compression schemes, end to end:
//!
//! 1. **Acceptance bar** (`fig_comp`'s headline, pinned here on the same
//!    workload): `censored` and `topk` reach the chain linreg target loss
//!    with **strictly fewer total transmitted bits** than `stochastic`.
//! 2. **Cross-runtime equivalence** — censored and top-k runs are
//!    bit-for-bit identical between the deterministic engine, the
//!    threaded runtime, and the simulated runtime on an ideal network,
//!    extending the equivalence suites beyond the stochastic scheme.

use qgadmm::config::{CompressorConfig, GadmmConfig, QuantConfig, SimConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::coordinator::simulated::SimulatedGadmm;
use qgadmm::coordinator::threaded::run_threaded;
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::figures::fig_comp::{comp_schemes, run_scheme, CompWorkload};
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::WorkerSolver;
use qgadmm::net::geometry::collinear;
use qgadmm::net::topology::Topology;

#[test]
fn censored_and_topk_beat_stochastic_on_bits_to_target() {
    // The fig_comp acceptance criterion, on the figure's own standard
    // workload and seed: every scheme reaches the target, and the
    // communication-adaptive schemes pay strictly fewer bits getting
    // there.
    let w = CompWorkload::standard();
    let seed = 1; // ExperimentConfig::default().seed — what the figure uses
    let mut bits = std::collections::BTreeMap::new();
    for (name, compressor) in comp_schemes() {
        if name == "full" {
            continue; // the figure's baseline; not part of the bar
        }
        let r = run_scheme(&w, Topology::line(w.workers), compressor, seed);
        assert!(
            r.bits_to_target.is_some(),
            "{name} failed to reach the target in {} iterations (final gap {:.3e})",
            r.iterations,
            r.final_gap
        );
        if name == "censored" {
            assert!(
                r.censored_rounds > 0,
                "censored run never censored — the threshold schedule is inert"
            );
        }
        bits.insert(name, r.bits_to_target.unwrap());
    }
    let stochastic = bits["stochastic"];
    assert!(
        bits["censored"] < stochastic,
        "censored must beat stochastic on bits-to-target: {} vs {stochastic}",
        bits["censored"]
    );
    assert!(
        bits["topk"] < stochastic,
        "topk must beat stochastic on bits-to-target: {} vs {stochastic}",
        bits["topk"]
    );
}

fn linreg_world(workers: usize) -> (LinRegDataset, Partition) {
    let spec = LinRegSpec {
        samples: 1_200,
        ..LinRegSpec::default()
    };
    let data = LinRegDataset::synthesize(&spec, 71);
    let partition = Partition::contiguous(data.samples(), workers);
    (data, partition)
}

/// Engine vs simulated runtime (ideal network) under `compressor`:
/// bit-for-bit per-iteration models, views, and communication tallies.
fn assert_sim_matches_engine(compressor: CompressorConfig, iters: usize, seed: u64) {
    let workers = 6;
    let (data, partition) = linreg_world(workers);
    let rho = 1600.0f32;
    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor,
        threads: 0,
    };

    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut engine = GadmmEngine::new(cfg.clone(), problem, Topology::line(workers), seed);
    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut sim = SimulatedGadmm::new(
        cfg,
        SimConfig::ideal(),
        problem,
        Topology::line(workers),
        collinear(workers, 40.0),
        seed,
    );

    for k in 0..iters {
        engine.iterate();
        assert!(sim.iterate());
        for p in 0..workers {
            assert_eq!(
                engine.theta_at(p),
                sim.theta_of(p),
                "θ diverged at position {p}, iteration {k}"
            );
            assert_eq!(
                engine.view_at(p),
                sim.view_of(p),
                "θ̂ diverged at position {p}, iteration {k}"
            );
        }
        assert_eq!(engine.comm().bits, sim.comm().bits, "bits diverged at {k}");
        assert_eq!(
            engine.comm().transmissions,
            sim.comm().transmissions,
            "transmissions diverged at {k}"
        );
        assert_eq!(
            engine.comm().censored,
            sim.comm().censored,
            "censored tallies diverged at {k}"
        );
    }
}

/// Engine vs threaded runtime under `compressor`: same final models, same
/// per-iteration objectives, same communication tallies.
fn assert_threaded_matches_engine(compressor: CompressorConfig, iters: u64, seed: u64) {
    let workers = 6;
    let (data, partition) = linreg_world(workers);
    let rho = 1600.0f32;
    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor,
        threads: 0,
    };
    let opts = RunOptions {
        iterations: iters,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };

    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut engine = GadmmEngine::new(cfg.clone(), problem, Topology::line(workers), seed);
    let eng_report = engine.run(&opts, |e| e.global_objective());

    let problem = LinRegProblem::new(&data, &partition, rho);
    let solvers: Vec<Box<dyn WorkerSolver>> = problem
        .into_workers()
        .into_iter()
        .map(|w| Box::new(w) as Box<dyn WorkerSolver>)
        .collect();
    let thr_report = run_threaded(&cfg, solvers, &opts, seed, |obj, _| obj).unwrap();

    for p in 0..workers {
        assert_eq!(
            engine.theta_at(p),
            thr_report.thetas[p].as_slice(),
            "theta diverged at position {p}"
        );
    }
    assert_eq!(eng_report.comm.bits, thr_report.comm.bits);
    assert_eq!(eng_report.comm.transmissions, thr_report.comm.transmissions);
    assert_eq!(eng_report.comm.censored, thr_report.comm.censored);
    for (a, b) in eng_report
        .recorder
        .points
        .iter()
        .zip(&thr_report.recorder.points)
    {
        assert_eq!(
            a.value, b.value,
            "objective diverged at iteration {}",
            a.iteration
        );
    }
}

/// A constant threshold that the early (large) updates clear and the late
/// (converged) updates do not — exercises both the sent and the censored
/// path within one run.
fn mixed_censoring() -> CompressorConfig {
    CompressorConfig::Censored {
        quant: QuantConfig::default(),
        tau0: 0.01,
        decay: 1.0,
    }
}

#[test]
fn censored_sim_matches_engine_on_ideal_network() {
    assert_sim_matches_engine(mixed_censoring(), 60, 2024);
}

#[test]
fn topk_sim_matches_engine_on_ideal_network() {
    assert_sim_matches_engine(CompressorConfig::TopK { frac: 0.4 }, 60, 2024);
}

#[test]
fn censored_threaded_matches_engine() {
    assert_threaded_matches_engine(mixed_censoring(), 60, 7);
}

#[test]
fn topk_threaded_matches_engine() {
    assert_threaded_matches_engine(CompressorConfig::TopK { frac: 0.4 }, 60, 7);
}

#[test]
fn mixed_censoring_actually_censors_and_sends() {
    // Guard the fixtures above: the constant-threshold run must take both
    // branches, otherwise the cross-runtime tests silently degrade to the
    // always-send case.
    let workers = 6;
    let (data, partition) = linreg_world(workers);
    let cfg = GadmmConfig {
        workers,
        rho: 1600.0,
        dual_step: 1.0,
        compressor: mixed_censoring(),
        threads: 0,
    };
    let problem = LinRegProblem::new(&data, &partition, 1600.0);
    let mut engine = GadmmEngine::new(cfg, problem, Topology::line(workers), 2024);
    for _ in 0..60 {
        engine.iterate();
    }
    assert!(engine.comm().transmissions > 0, "nothing was ever sent");
    assert!(engine.comm().censored > 0, "nothing was ever censored");
}
