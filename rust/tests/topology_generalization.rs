//! The neighbor-API generalization's regression harness.
//!
//! 1. **Chain pin** — an independent reference implementation of the
//!    *pre-redesign* chain algorithm (hard-wired left/right neighbor math,
//!    eqs. (14)–(18), built straight from sufficient statistics) must
//!    match the degree-general engine bit-for-bit over 50 iterations,
//!    quantized and full precision. This pins the edge-list/`NeighborCtx`
//!    migration to the original trajectories.
//! 2. **Topology convergence** — the `--topology ring/star/grid2d`
//!    configurations reach the chain's loss-gap levels on the same
//!    workload (the generalized-GADMM claim of arXiv:2009.06459).
//! 3. **Cross-runtime equivalence off-chain** — the threaded runtime on a
//!    ring and the simulated runtime (ideal network) on a star are
//!    bit-for-bit the engine, extending the chain-only equivalence
//!    suites to the new graphs.

use qgadmm::config::{CompressorConfig, GadmmConfig, QuantConfig, SimConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::coordinator::simulated::SimulatedGadmm;
use qgadmm::coordinator::threaded::run_threaded_on;
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec, WorkerStats};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::WorkerSolver;
use qgadmm::net::geometry::collinear;
use qgadmm::net::topology::{Topology, TopologyKind};
use qgadmm::quant::{self, BitPolicy, StochasticQuantizer};
use qgadmm::util::rng::Rng;

fn world(workers: usize, samples: usize) -> (LinRegDataset, Partition) {
    let spec = LinRegSpec {
        samples,
        ..LinRegSpec::default()
    };
    let data = LinRegDataset::synthesize(&spec, 71);
    let partition = Partition::contiguous(data.samples(), workers);
    (data, partition)
}

/// The pre-redesign chain algorithm, implemented from scratch: explicit
/// left/right neighbors, one λ per chain link, heads at even positions.
/// Every floating-point expression mirrors the original
/// `LinRegWorker::solve` / engine dual update exactly.
struct ChainReference {
    stats: Vec<WorkerStats>,
    theta: Vec<Vec<f32>>,
    view: Vec<Vec<f32>>,
    lambda: Vec<Vec<f32>>,
    quantizers: Option<Vec<StochasticQuantizer>>,
    rngs: Vec<Rng>,
    rho: f64,
    bits: u64,
}

impl ChainReference {
    fn new(data: &LinRegDataset, partition: &Partition, rho: f32, quant: bool, seed: u64) -> Self {
        let n = partition.workers();
        let d = data.features();
        let stats: Vec<WorkerStats> = (0..n)
            .map(|w| {
                let (lo, hi) = partition.bounds(w);
                data.sufficient_stats(lo, hi)
            })
            .collect();
        let mut root = Rng::seed_from_u64(seed);
        let rngs = (0..n).map(|p| root.fork(p as u64)).collect();
        let quantizers = quant.then(|| {
            (0..n)
                .map(|_| StochasticQuantizer::new(d, BitPolicy::Fixed(2)))
                .collect()
        });
        ChainReference {
            stats,
            theta: vec![vec![0.0; d]; n],
            view: vec![vec![0.0; d]; n],
            lambda: vec![vec![0.0; d]; n - 1],
            quantizers,
            rngs,
            rho: rho as f64,
            bits: 0,
        }
    }

    fn solve_position(&mut self, p: usize) {
        let n = self.theta.len();
        let d = self.theta[p].len();
        let rho = self.rho;
        // rhs = b + [left](λ_{p−1} + ρ·v_{p−1}) + [right](−λ_p + ρ·v_{p+1})
        let mut rhs = self.stats[p].b.clone();
        let mut deg = 0usize;
        if p > 0 {
            deg += 1;
            for i in 0..d {
                rhs[i] += self.lambda[p - 1][i] as f64 + rho * self.view[p - 1][i] as f64;
            }
        }
        if p + 1 < n {
            deg += 1;
            for i in 0..d {
                rhs[i] += -(self.lambda[p][i] as f64) + rho * self.view[p + 1][i] as f64;
            }
        }
        let mut m = self.stats[p].a.clone();
        m.add_diag(rho * deg as f64);
        let sol = m.solve_spd(&rhs).expect("A + ρ·deg·I is SPD");
        for i in 0..d {
            self.theta[p][i] = sol[i] as f32;
        }
    }

    fn broadcast_position(&mut self, p: usize) {
        let d = self.theta[p].len();
        match self.quantizers.as_mut() {
            Some(qs) => {
                let (bits, _radius) =
                    qs[p].quantize_into(&self.theta[p], &mut self.rngs[p], &mut self.view[p]);
                self.bits += quant::payload_bits(bits, d);
            }
            None => {
                self.view[p].copy_from_slice(&self.theta[p]);
                self.bits += 32 * d as u64;
            }
        }
    }

    fn iterate(&mut self) {
        let n = self.theta.len();
        for phase in 0..2 {
            let mut p = phase;
            while p < n {
                self.solve_position(p);
                self.broadcast_position(p);
                p += 2;
            }
        }
        // λ_i ← λ_i + α·ρ·(v_i − v_{i+1}), α = 1 (so step = ρ exactly, as
        // the engine's `dual_step * rho` computes with dual_step = 1.0).
        let step = self.rho as f32;
        for i in 0..n - 1 {
            for j in 0..self.lambda[i].len() {
                let delta = step * (self.view[i][j] - self.view[i + 1][j]);
                self.lambda[i][j] += delta;
            }
        }
    }
}

fn assert_engine_matches_reference(quant: bool, workers: usize, iters: usize, seed: u64) {
    let (data, partition) = world(workers, 1_400);
    let rho = 1600.0f32;

    let mut reference = ChainReference::new(&data, &partition, rho, quant, seed);
    for _ in 0..iters {
        reference.iterate();
    }

    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor: quant.then(QuantConfig::default).into(),
        threads: 1,
    };
    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut engine = GadmmEngine::new(cfg, problem, Topology::line(workers), seed);
    for _ in 0..iters {
        engine.iterate();
    }

    for p in 0..workers {
        assert_eq!(
            engine.theta_at(p),
            reference.theta[p].as_slice(),
            "θ diverged from the pre-redesign trajectory at position {p}"
        );
        assert_eq!(
            engine.view_at(p),
            reference.view[p].as_slice(),
            "θ̂ diverged from the pre-redesign trajectory at position {p}"
        );
    }
    for l in 0..workers - 1 {
        assert_eq!(
            engine.lambda_at(l),
            reference.lambda[l].as_slice(),
            "λ diverged from the pre-redesign trajectory on link {l}"
        );
    }
    assert_eq!(engine.comm().bits, reference.bits, "bit accounting diverged");
}

#[test]
fn chain_trajectories_pinned_quantized() {
    assert_engine_matches_reference(true, 6, 50, 2024);
}

#[test]
fn chain_trajectories_pinned_full_precision() {
    assert_engine_matches_reference(false, 5, 50, 7);
}

/// The acceptance-criteria integration test: `train-linreg --topology
/// ring|star|grid2d` (the same `TopologyKind` path the CLI takes) reaches
/// the chain's loss-gap levels on the shared workload.
#[test]
fn nonchain_topologies_reach_the_chain_loss_gap() {
    let workers = 8;
    let (data, partition) = world(workers, 1_400);
    let (_, f_star) = data.optimum();
    let rho = 1600.0f32;

    let run = |topo: Topology, quant: Option<QuantConfig>, iters: usize| -> f64 {
        let cfg = GadmmConfig {
            workers,
            rho,
            dual_step: 1.0,
            compressor: quant.into(),
            threads: 0,
        };
        let problem = LinRegProblem::new(&data, &partition, rho);
        let mut engine = GadmmEngine::new(cfg, problem, topo, 11);
        let start = (engine.global_objective() - f_star).abs();
        for _ in 0..iters {
            engine.iterate();
        }
        (engine.global_objective() - f_star).abs() / start.max(1e-12)
    };

    let chain = run(Topology::line(workers), None, 800);
    assert!(chain < 1e-3, "chain did not contract: {chain}");
    for name in ["ring", "star", "grid2d"] {
        let topo = TopologyKind::parse(name)
            .unwrap()
            .build(workers, 11)
            .unwrap();
        assert!(topo.validate());
        let rel = run(topo, None, 800);
        assert!(
            rel < 1e-2,
            "{name} did not reach the chain's loss-gap levels: relative gap {rel} (chain {chain})"
        );
    }
    // Quantized ring: same fixed point, quantization-noise tolerance.
    let ring = TopologyKind::Ring.build(workers, 11).unwrap();
    let rel_q = run(ring, Some(QuantConfig::default()), 900);
    assert!(rel_q < 5e-2, "quantized ring relative gap {rel_q}");
}

/// The threaded runtime's mailbox wiring follows the topology edge list;
/// on a ring it must stay bit-for-bit the engine (the
/// `threaded_equivalence` guarantee, extended off-chain).
#[test]
fn threaded_ring_matches_engine_bit_for_bit() {
    let workers = 6;
    let (data, partition) = world(workers, 1_200);
    let rho = 1600.0f32;
    let iters = 40u64;
    let seed = 99u64;
    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor: CompressorConfig::Stochastic(QuantConfig::default()),
        threads: 0,
    };
    let topo = Topology::ring(workers).unwrap();

    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut engine = GadmmEngine::new(cfg.clone(), problem, topo.clone(), seed);
    let opts = RunOptions {
        iterations: iters,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let eng_report = engine.run(&opts, |e| e.global_objective());

    let problem = LinRegProblem::new(&data, &partition, rho);
    let solvers: Vec<Box<dyn WorkerSolver>> = problem
        .into_workers()
        .into_iter()
        .map(|w| Box::new(w) as Box<dyn WorkerSolver>)
        .collect();
    let thr_report = run_threaded_on(
        &topo,
        &cfg,
        solvers,
        &opts,
        seed,
        None,
        true,
        |obj, _| obj,
        &mut qgadmm::metrics::NoopObserver,
    )
    .unwrap();

    for p in 0..workers {
        assert_eq!(
            engine.theta_at(p),
            thr_report.thetas[p].as_slice(),
            "theta diverged at ring position {p}"
        );
    }
    assert_eq!(eng_report.comm.bits, thr_report.comm.bits);
    assert_eq!(
        eng_report.recorder.points.len(),
        thr_report.recorder.points.len()
    );
    for (a, b) in eng_report
        .recorder
        .points
        .iter()
        .zip(&thr_report.recorder.points)
    {
        assert_eq!(a.value, b.value, "objective diverged at iteration {}", a.iteration);
    }
}

/// The simulated runtime on an ideal network is the engine, even with a
/// degree-4 hub (star) — per-link mirrors and duals line up with the
/// engine's per-edge state.
#[test]
fn simulated_star_matches_engine_on_ideal_network() {
    let workers = 5;
    let (data, partition) = world(workers, 1_200);
    let rho = 1600.0f32;
    let seed = 41u64;
    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor: CompressorConfig::Stochastic(QuantConfig::default()),
        threads: 0,
    };
    let topo = Topology::star(workers);

    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut engine = GadmmEngine::new(cfg.clone(), problem, topo.clone(), seed);

    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut sim = SimulatedGadmm::new(
        cfg,
        SimConfig::ideal(),
        problem,
        topo,
        collinear(workers, 40.0),
        seed,
    );

    for k in 0..30 {
        engine.iterate();
        assert!(sim.iterate());
        for p in 0..workers {
            // Identity order: worker id == position.
            assert_eq!(
                engine.theta_at(p),
                sim.theta_of(p),
                "θ diverged at position {p}, iteration {k}"
            );
            assert_eq!(
                engine.view_at(p),
                sim.view_of(p),
                "θ̂ diverged at position {p}, iteration {k}"
            );
        }
        assert_eq!(engine.comm().bits, sim.comm().bits, "bits diverged at {k}");
    }
}
