//! The shared membership/resync protocol over real sockets: the tcp
//! driver's fault handling is the *same* state machine the simulator
//! promotes into `coordinator::membership`, so a scheduled (announced)
//! dropout over TCP must reproduce the simulator's run bit for bit —
//! curve, communication ledger, and surviving models — and a *detected*
//! crash (sockets break mid-run, survivors negotiate a re-stitch
//! boundary) must recover to a smaller healthy chain.

use qgadmm::coordinator::engine::RunOptions;
use qgadmm::prelude::*;

const WORKERS: usize = 6;
const SEED: u64 = 424;

fn dropout_sim_cfg(dropouts: Vec<Dropout>) -> SimConfig {
    let mut sim = SimConfig::ideal();
    sim.dropouts = dropouts;
    sim
}

fn session(driver: DriverKind, sim: SimConfig, iterations: u64) -> Session {
    Session::new(ProblemKind::LinReg)
        .quick(true)
        .workers(WORKERS)
        .seed(SEED)
        .driver(driver)
        .sim_config(sim)
        .options(RunOptions {
            iterations,
            eval_every: 1,
            stop_below: None,
            stop_above: None,
            ..RunOptions::default()
        })
}

fn assert_bit_equal(name: &str, a: &RunSummary, b: &RunSummary) {
    assert_eq!(a.recorder.points.len(), b.recorder.points.len(), "{name}: curve lengths");
    for (pa, pb) in a.recorder.points.iter().zip(&b.recorder.points) {
        assert_eq!(pa.iteration, pb.iteration, "{name}: iteration axis");
        assert_eq!(
            pa.value.to_bits(),
            pb.value.to_bits(),
            "{name}: metric diverged at iteration {} ({} vs {})",
            pa.iteration,
            a.driver,
            b.driver
        );
        assert_eq!(pa.bits, pb.bits, "{name}: bit curve at {}", pa.iteration);
        assert_eq!(pa.comm_rounds, pb.comm_rounds, "{name}: round counting");
    }
    assert_eq!(a.iterations_run, b.iterations_run, "{name}: run lengths");
    assert_eq!(a.comm.bits, b.comm.bits, "{name}: total bits");
    assert_eq!(a.comm.transmissions, b.comm.transmissions, "{name}: transmissions");
    assert_eq!(a.comm.censored, b.comm.censored, "{name}: censored tallies");
    assert_eq!(a.thetas, b.thetas, "{name}: surviving models");
}

/// The announced-fault pin: a scheduled dropout over real loopback
/// sockets is the simulator's dropout bit for bit — the victim leaves at
/// its iteration boundary, the survivors re-stitch over the same
/// nearest-neighbor chain, pay the same per-survivor resync bits, and
/// continue to the same models.
#[test]
fn announced_dropout_on_tcp_matches_the_simulator() {
    let dropouts = vec![Dropout {
        worker: 2,
        at_iteration: 5,
    }];
    let sim = session(DriverKind::Sim, dropout_sim_cfg(dropouts.clone()), 30)
        .run()
        .unwrap();
    let tcp = session(DriverKind::Tcp, dropout_sim_cfg(dropouts), 30)
        .run()
        .unwrap();
    assert_eq!(sim.driver, "sim");
    assert_eq!(tcp.driver, "tcp");
    assert_eq!(tcp.thetas.len(), WORKERS - 1, "one worker left the fleet");
    assert_bit_equal("announced dropout", &sim, &tcp);
}

/// Two staggered dropouts still agree — the second re-stitch happens on
/// an already-shrunk chain, exercising the membership layer's global-id
/// bookkeeping rather than a one-shot special case.
#[test]
fn staggered_dropouts_on_tcp_match_the_simulator() {
    let dropouts = vec![
        Dropout {
            worker: 1,
            at_iteration: 4,
        },
        Dropout {
            worker: 4,
            at_iteration: 9,
        },
    ];
    let sim = session(DriverKind::Sim, dropout_sim_cfg(dropouts.clone()), 25)
        .run()
        .unwrap();
    let tcp = session(DriverKind::Tcp, dropout_sim_cfg(dropouts), 25)
        .run()
        .unwrap();
    assert_eq!(tcp.thetas.len(), WORKERS - 2);
    assert_bit_equal("staggered dropouts", &sim, &tcp);
}

/// The detected-fault path: the victim's sockets simply break mid-run
/// (no announcement), the survivors discover the crash through their
/// connection readers, agree on a re-stitch boundary through the shared
/// membership layer, and run the remaining iterations on the healthy
/// chain. Detection timing is wall-clock dependent, so this pins the
/// protocol outcome (fleet size, full iteration count, finite models),
/// not a bit-exact curve.
#[test]
fn detected_crash_recovers_over_sockets() {
    let dropouts = vec![Dropout {
        worker: 1,
        at_iteration: 6,
    }];
    let summary = session(DriverKind::Tcp, dropout_sim_cfg(dropouts), 40)
        .tcp_config(TcpConfig {
            fault_mode: TcpFaultMode::Detected,
            ..TcpConfig::default()
        })
        .run()
        .unwrap();
    assert_eq!(summary.driver, "tcp");
    assert_eq!(
        summary.iterations_run, 40,
        "survivors must complete the full run after the re-stitch"
    );
    assert_eq!(summary.thetas.len(), WORKERS - 1);
    assert!(summary.final_value().is_finite());
    for theta in &summary.thetas {
        assert!(theta.iter().all(|x| x.is_finite()), "survivor model diverged");
    }
}

/// The protocol is visible in the telemetry stream: an announced dropout
/// over TCP emits the same transport narrative the simulator does —
/// Dropout, one Resync per survivor, then the Restitch marker — all at
/// the scheduled iteration.
#[cfg(feature = "telemetry")]
#[test]
fn announced_dropout_emits_the_shared_membership_trace() {
    struct Collector {
        events: Vec<TraceEvent>,
    }
    impl Observer for Collector {
        fn on_record(&mut self, record: &Record) {
            self.events.push(record.event.clone());
        }
        fn wants_telemetry(&self) -> bool {
            true
        }
    }

    let dropouts = vec![Dropout {
        worker: 2,
        at_iteration: 5,
    }];
    let mut obs = Collector { events: Vec::new() };
    session(DriverKind::Tcp, dropout_sim_cfg(dropouts), 12)
        .run_observed(&mut obs)
        .unwrap();

    let dropout: Vec<_> = obs
        .events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Dropout { iteration, worker } => Some((*iteration, *worker)),
            _ => None,
        })
        .collect();
    assert_eq!(dropout, vec![(5, 2)], "exactly one dropout, at its schedule");

    let resyncs = obs
        .events
        .iter()
        .filter(|e| matches!(e, TraceEvent::Resync { iteration: 5, .. }))
        .count();
    assert_eq!(resyncs, WORKERS - 1, "every survivor resyncs its mirrors");

    let restitch: Vec<_> = obs
        .events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Restitch {
                iteration,
                survivors,
            } => Some((*iteration, *survivors)),
            _ => None,
        })
        .collect();
    assert_eq!(restitch, vec![(5, WORKERS - 1)], "one re-stitch over the survivors");
}

/// Detected crashes narrate too: survivors report who they lost
/// (Disconnected) before the shared layer re-stitches.
#[cfg(feature = "telemetry")]
#[test]
fn detected_crash_emits_disconnects_and_a_restitch() {
    struct Collector {
        events: Vec<TraceEvent>,
    }
    impl Observer for Collector {
        fn on_record(&mut self, record: &Record) {
            self.events.push(record.event.clone());
        }
        fn wants_telemetry(&self) -> bool {
            true
        }
    }

    let dropouts = vec![Dropout {
        worker: 1,
        at_iteration: 6,
    }];
    let mut obs = Collector { events: Vec::new() };
    session(DriverKind::Tcp, dropout_sim_cfg(dropouts), 40)
        .tcp_config(TcpConfig {
            fault_mode: TcpFaultMode::Detected,
            ..TcpConfig::default()
        })
        .run_observed(&mut obs)
        .unwrap();

    let disconnects = obs
        .events
        .iter()
        .filter(|e| matches!(e, TraceEvent::Disconnected { peer: 1, .. }))
        .count();
    assert!(disconnects >= 1, "someone must report the broken socket");
    let restitches = obs
        .events
        .iter()
        .filter(|e| matches!(e, TraceEvent::Restitch { survivors, .. } if *survivors == WORKERS - 1))
        .count();
    assert_eq!(restitches, 1, "exactly one re-stitch over the survivors");
}
