// Fixture: must fire the unsuppressible `tidy-allow` meta-lint three
// ways — missing reason, unknown lint name, missing close paren.
pub fn a() {} // tidy:allow(determinism-collections)
pub fn b() {} // tidy:allow(no-such-lint): the lint name is wrong
pub fn c() {} // tidy:allow(panic-safety
