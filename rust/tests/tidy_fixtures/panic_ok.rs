// Fixture: must pass `panic-safety` clean even under a protocol-critical
// label — typed fallbacks in shipping code, free unwraps only after the
// top-level `#[cfg(test)]` marker.
pub fn parse_header(b: &[u8]) -> Option<u32> {
    let first = b.first()?;
    Some(u32::from(*first))
}

pub fn rho_or_default(v: Option<f64>) -> f64 {
    v.unwrap_or(1.0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unit() {
        assert_eq!(super::rho_or_default(None), 1.0);
        super::parse_header(&[7]).unwrap();
    }
}
