// Fixture: must fire `lock-order` three ways when labeled as a
// lock-disciplined file — missing annotation, malformed rank, and a rank
// inversion inside one function.
pub fn publish(&self) {
    let mut s = self.state.lock_unpoisoned();
    *s += 1;
}

pub fn malformed(&self) {
    let _g = self.state.lock_unpoisoned(); // lock-order: leaf lock with no rank
}

pub fn inverted(&self) {
    // lock-order: 20 cluster table first
    let _a = self.cluster.lock_unpoisoned();
    // lock-order: 10 rho latch second — wrong way around
    let _b = self.latch.lock_unpoisoned();
}
