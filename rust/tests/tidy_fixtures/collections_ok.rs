// Fixture: must pass `determinism-collections` clean — ordered maps, a
// hash-container mention in prose only, and a properly suppressed use.
use std::collections::BTreeMap;

// A HashMap here would be flagged; BTreeMap iterates in key order.
pub fn route_table() -> BTreeMap<usize, usize> {
    BTreeMap::new()
}

// tidy:allow(determinism-collections): profiling scratch map, never iterated
use std::collections::HashMap;

pub fn scratch_len(m: &HashMap<usize, usize>) -> usize { // tidy:allow(determinism-collections): same scratch map
    m.len()
}
