// Fixture: must fire `determinism-collections` when labeled as a file in
// a determinism-scoped directory (never compiled; scanned by tests/tidy.rs).
use std::collections::HashMap;

pub fn route_table() -> HashMap<usize, usize> {
    HashMap::new()
}
