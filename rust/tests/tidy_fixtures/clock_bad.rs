// Fixture: must fire `determinism-clock` twice when labeled under src/
// outside src/telemetry/.
use std::time::Instant;

pub fn now_secs() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn epoch() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
