// Fixture: must pass hygiene clean — only declared features are cfg'd.
#[cfg(feature = "telemetry")]
pub fn traced() {}

#[cfg(not(feature = "telemetry"))]
pub fn untraced() {}
