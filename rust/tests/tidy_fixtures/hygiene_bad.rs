// Fixture: must fire `hygiene-features` (undeclared cfg feature) and the
// unsafe-token hygiene lint.
#[cfg(feature = "quantum-teleport")]
pub fn teleport() {}

pub unsafe fn raw_read(p: *const u8) -> u8 {
    *p
}
