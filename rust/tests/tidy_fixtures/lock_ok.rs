// Fixture: must pass `lock-order` clean — every acquisition annotated,
// ranks nondecreasing per function, watermark reset at function
// boundaries.
pub fn publish(&self) {
    // lock-order: 10 rho latch is a leaf lock
    let mut s = self.state.lock_unpoisoned();
    *s += 1;
}

pub fn sweep(&self) {
    // lock-order: 10 rho latch first
    let _a = self.latch.lock_unpoisoned();
    // lock-order: 20 cluster table after the latch
    let _b = self.cluster.lock_unpoisoned();
}

pub fn fresh_function_resets_the_watermark(&self) {
    // lock-order: 10 back down to the latch rank
    let _g = self.latch.lock_unpoisoned();
}
