// Fixture: must fire `panic-safety` twice when labeled as a
// protocol-critical file.
pub fn parse_header(b: &[u8]) -> u32 {
    let first = b.first().unwrap();
    if *first != 0xA9 {
        panic!("bad magic");
    }
    u32::from(*first)
}
