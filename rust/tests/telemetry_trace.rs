//! Cross-driver trace determinism — the telemetry analogue of
//! `session_equivalence`: on an ideal network with a shared seed, the
//! engine, threaded, simulated, and tcp drivers must emit the *same
//! ordered event sequence* (timestamps stripped, transport events
//! excluded — frame deliveries, connection bring-up, and dropouts exist
//! only where a network does).
//!
//! This is the golden-trace pin: any reordering of the canonical
//! per-iteration sequence (IterStart, head phase with its compresses,
//! tail phase, dual phase, IterEnd, then evals) on any driver is a
//! breaking change to the Observer contract.
#![cfg(feature = "telemetry")]

use qgadmm::coordinator::engine::RunOptions;
use qgadmm::prelude::*;

struct Collector {
    records: Vec<Record>,
}

impl Observer for Collector {
    fn on_record(&mut self, record: &Record) {
        self.records.push(record.clone());
    }

    fn wants_telemetry(&self) -> bool {
        true
    }
}

/// Run a quick linreg session on `kind` and return the non-transport
/// event sequence (timestamps dropped).
fn golden_run(kind: DriverKind, opts: RunOptions) -> Vec<TraceEvent> {
    let mut obs = Collector {
        records: Vec::new(),
    };
    let summary = Session::new(ProblemKind::LinReg)
        .quick(true)
        .workers(6)
        .driver(kind)
        .seed(11)
        .sim_config(SimConfig::ideal())
        .options(opts)
        .run_observed(&mut obs)
        .unwrap_or_else(|e| panic!("{} failed: {e}", kind.name()));
    assert!(
        !summary.metrics.is_empty(),
        "{}: a telemetry run must snapshot metrics",
        kind.name()
    );
    // Timestamps are driver-specific (wall clock vs virtual clock) and
    // nondecreasing; the *order* is the cross-driver contract.
    let mut last = 0u64;
    for rec in &obs.records {
        assert!(rec.t_ns >= last, "{}: timestamps regressed", kind.name());
        last = rec.t_ns;
    }
    obs.records
        .into_iter()
        .map(|r| r.event)
        .filter(|e| !e.is_transport())
        .collect()
}

#[test]
fn drivers_emit_one_golden_trace_on_an_ideal_network() {
    let opts = RunOptions {
        iterations: 5,
        eval_every: 2,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let engine = golden_run(DriverKind::Engine, opts.clone());
    let threaded = golden_run(DriverKind::Threaded, opts.clone());
    let sim = golden_run(DriverKind::Sim, opts.clone());
    let tcp = golden_run(DriverKind::Tcp, opts);

    // 6 workers: IterStart + 3 phase spans (6 records) + 6 compresses +
    // IterEnd = 14 per iteration; evals at k = 2 and 4.
    assert_eq!(engine.len(), 5 * 14 + 2);
    assert_eq!(engine, threaded, "engine vs threaded traces diverge");
    assert_eq!(engine, sim, "engine vs sim traces diverge");
    assert_eq!(engine, tcp, "engine vs tcp traces diverge");

    // Spot-check the canonical shape of iteration 1: heads (positions
    // 0, 2, 4) compress inside the head phase, tails inside the tail
    // phase, dual phase is span-only.
    let names: Vec<&str> = engine[..14].iter().map(|e| e.name()).collect();
    assert_eq!(
        names,
        [
            "iter_start",
            "phase_start",
            "compress",
            "compress",
            "compress",
            "phase_end",
            "phase_start",
            "compress",
            "compress",
            "compress",
            "phase_end",
            "phase_start",
            "phase_end",
            "iter_end",
        ]
    );
    let workers: Vec<usize> = engine[..14]
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Compress { worker, .. } => Some(*worker),
            _ => None,
        })
        .collect();
    assert_eq!(workers, [0, 2, 4, 1, 3, 5]);
}

#[test]
fn early_stop_cascade_traces_identically() {
    // A loss-gap threshold crossed at the first eval: every driver must
    // end its trace with Eval followed by EarlyStop at the same
    // iteration.
    let opts = RunOptions {
        iterations: 50,
        eval_every: 2,
        stop_below: Some(f64::MAX),
        stop_above: None,
        ..RunOptions::default()
    };
    let engine = golden_run(DriverKind::Engine, opts.clone());
    let threaded = golden_run(DriverKind::Threaded, opts.clone());
    let sim = golden_run(DriverKind::Sim, opts.clone());
    let tcp = golden_run(DriverKind::Tcp, opts);

    assert_eq!(engine, threaded, "engine vs threaded early-stop traces diverge");
    assert_eq!(engine, sim, "engine vs sim early-stop traces diverge");
    assert_eq!(engine, tcp, "engine vs tcp early-stop traces diverge");
    // Two full iterations, then the eval that crosses and the stop.
    assert_eq!(engine.len(), 2 * 14 + 2);
    assert_eq!(engine[engine.len() - 2].name(), "eval");
    let last = engine.last().unwrap();
    assert_eq!(last.name(), "early_stop");
    assert_eq!(last.iteration(), 2);
}
