//! Cross-runtime equivalence **through the Session API**: one
//! parameterized test asserting engine vs threaded vs sim bit-equality
//! per compression scheme × topology, built entirely from `Session`
//! builders (no hand-assembled problems, solvers, or metric closures —
//! that copy-pasted setup lives on in `threaded_equivalence.rs` /
//! `sim_determinism.rs` only as the historical pins).
//!
//! Every combination runs the same quick linreg task on all three
//! drivers and must agree bit-for-bit on the metric curve, the
//! communication totals, and the final models. A second test pins the
//! new uniform early-stopping behavior (the threaded runtime used to
//! take a bare iteration count), and a third runs the `logreg` registry
//! entry across all three drivers.

use qgadmm::config::{CompressorConfig, QuantConfig, SimConfig};
use qgadmm::coordinator::engine::RunOptions;
use qgadmm::metrics::report::RunSummary;
use qgadmm::net::topology::TopologyKind;
use qgadmm::runtime::session::{DriverKind, ProblemKind, Session};

const WORKERS: usize = 6;
const SEED: u64 = 2024;

fn schemes() -> Vec<(&'static str, CompressorConfig)> {
    vec![
        ("stochastic", CompressorConfig::Stochastic(QuantConfig::default())),
        ("full", CompressorConfig::FullPrecision),
        (
            // A constant threshold the early (large) updates clear and the
            // late ones do not — exercises both the sent and the censored
            // path within one run.
            "censored",
            CompressorConfig::Censored {
                quant: QuantConfig::default(),
                tau0: 0.01,
                decay: 1.0,
            },
        ),
        ("topk", CompressorConfig::TopK { frac: 0.5 }),
    ]
}

fn session(
    problem: ProblemKind,
    driver: DriverKind,
    topology: TopologyKind,
    compressor: CompressorConfig,
    opts: RunOptions,
) -> Session {
    let mut s = Session::new(problem)
        .quick(true)
        .workers(WORKERS)
        .seed(SEED)
        .driver(driver)
        .topology(topology)
        .compressor(compressor)
        .options(opts);
    if driver == DriverKind::Sim {
        // The ideal-network limit is the regime in which the simulator is
        // the engine bit-for-bit (the sim_determinism guarantee).
        s = s.sim_config(SimConfig::ideal());
    }
    s
}

fn assert_bit_equal(name: &str, a: &RunSummary, b: &RunSummary) {
    assert_eq!(
        a.recorder.points.len(),
        b.recorder.points.len(),
        "{name}: curve lengths diverged ({} vs {})",
        a.driver,
        b.driver
    );
    for (pa, pb) in a.recorder.points.iter().zip(&b.recorder.points) {
        assert_eq!(pa.iteration, pb.iteration, "{name}: iteration axis diverged");
        assert_eq!(
            pa.value.to_bits(),
            pb.value.to_bits(),
            "{name}: metric diverged at iteration {} ({} vs {})",
            pa.iteration,
            a.driver,
            b.driver
        );
        assert_eq!(pa.bits, pb.bits, "{name}: bit curve diverged at {}", pa.iteration);
        assert_eq!(pa.comm_rounds, pb.comm_rounds, "{name}: round counting diverged");
    }
    assert_eq!(a.iterations_run, b.iterations_run, "{name}: run lengths diverged");
    assert_eq!(a.comm.bits, b.comm.bits, "{name}: total bits diverged");
    assert_eq!(
        a.comm.transmissions, b.comm.transmissions,
        "{name}: transmission tallies diverged"
    );
    assert_eq!(a.comm.censored, b.comm.censored, "{name}: censored tallies diverged");
    assert_eq!(a.thetas.len(), b.thetas.len(), "{name}: fleet sizes diverged");
    for (p, (ta, tb)) in a.thetas.iter().zip(&b.thetas).enumerate() {
        assert_eq!(
            ta, tb,
            "{name}: final theta diverged at position {p} ({} vs {})",
            a.driver, b.driver
        );
    }
}

/// The tentpole guarantee: scheme × topology, all three drivers, one
/// Session API, bit-for-bit identical runs.
#[test]
fn engine_threaded_and_sim_agree_per_scheme_and_topology() {
    let opts = RunOptions {
        iterations: 40,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    for topology in [TopologyKind::Line, TopologyKind::Ring, TopologyKind::Star] {
        for (scheme, compressor) in schemes() {
            let name = format!("{scheme} on {}", topology.name());
            let run = |driver| {
                session(ProblemKind::LinReg, driver, topology, compressor.clone(), opts.clone())
                    .run()
                    .unwrap_or_else(|e| panic!("{name}: {driver:?} failed: {e}"))
            };
            let engine = run(DriverKind::Engine);
            let threaded = run(DriverKind::Threaded);
            let sim = run(DriverKind::Sim);
            assert_eq!(engine.driver, "engine");
            assert_eq!(threaded.driver, "threaded");
            assert_eq!(sim.driver, "sim");
            assert_bit_equal(&name, &engine, &threaded);
            assert_bit_equal(&name, &engine, &sim);
        }
    }
}

/// The tcp driver on ideal loopback is the simulator bit-for-bit: same
/// seed, same curve, same communication totals, same final models —
/// across the quantizing, censoring, and per-block compressors on both
/// line and ring topologies. Real sockets change the transport, not one
/// bit of the algorithm.
#[test]
fn tcp_matches_sim_per_scheme_and_topology() {
    let opts = RunOptions {
        iterations: 30,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let layered =
        CompressorConfig::parse("layers:all=stochastic@4", QuantConfig::default()).unwrap();
    let tcp_schemes: Vec<(&str, CompressorConfig)> = vec![
        ("stochastic", CompressorConfig::Stochastic(QuantConfig::default())),
        (
            "censored",
            CompressorConfig::Censored {
                quant: QuantConfig::default(),
                tau0: 0.01,
                decay: 1.0,
            },
        ),
        ("layers", layered),
    ];
    for topology in [TopologyKind::Line, TopologyKind::Ring] {
        for (scheme, compressor) in &tcp_schemes {
            let name = format!("{scheme} on {}", topology.name());
            let run = |driver| {
                session(
                    ProblemKind::LinReg,
                    driver,
                    topology,
                    compressor.clone(),
                    opts.clone(),
                )
                .run()
                .unwrap_or_else(|e| panic!("{name}: {driver:?} failed: {e}"))
            };
            let sim = run(DriverKind::Sim);
            let tcp = run(DriverKind::Tcp);
            assert_eq!(sim.driver, "sim");
            assert_eq!(tcp.driver, "tcp");
            assert_bit_equal(&name, &sim, &tcp);
        }
    }
}

/// RunOptions are honored uniformly: the same early-stop threshold makes
/// every driver halt at the same iteration with the same final state.
#[test]
fn early_stopping_is_uniform_across_drivers() {
    let probe_opts = RunOptions {
        iterations: 40,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let probe = session(
        ProblemKind::LinReg,
        DriverKind::Engine,
        TopologyKind::Line,
        CompressorConfig::Stochastic(QuantConfig::default()),
        probe_opts,
    )
    .run()
    .unwrap();
    // A threshold the run crosses mid-flight (strictly between the
    // values at iterations 15 and 40).
    let target = probe.recorder.points[15].value;
    assert!(
        probe.final_value() < target,
        "probe run must keep descending past iteration 15"
    );

    let opts = RunOptions {
        iterations: 40,
        eval_every: 1,
        stop_below: Some(target),
        stop_above: None,
        ..RunOptions::default()
    };
    let run = |driver| {
        session(
            ProblemKind::LinReg,
            driver,
            TopologyKind::Line,
            CompressorConfig::Stochastic(QuantConfig::default()),
            opts.clone(),
        )
        .run()
        .unwrap()
    };
    let engine = run(DriverKind::Engine);
    let threaded = run(DriverKind::Threaded);
    let sim = run(DriverKind::Sim);
    assert!(
        engine.iterations_run < 40,
        "threshold must trigger an early stop (ran {})",
        engine.iterations_run
    );
    assert_bit_equal("early stop", &engine, &threaded);
    assert_bit_equal("early stop", &engine, &sim);
}

/// The Observer contract is driver-uniform too: the same Session on the
/// engine and the threaded runtime streams the identical broadcast-event
/// sequence (heads ascending then tails ascending, per iteration) and
/// the identical eval cadence.
#[test]
fn observer_event_streams_are_identical_across_engine_and_threaded() {
    use qgadmm::metrics::{BroadcastEvent, Observer};

    #[derive(Default)]
    struct Spy {
        events: Vec<BroadcastEvent>,
        evals: Vec<u64>,
    }
    impl Observer for Spy {
        fn on_eval(&mut self, point: &qgadmm::metrics::recorder::CurvePoint) {
            self.evals.push(point.iteration);
        }
        fn on_broadcast(&mut self, event: &BroadcastEvent) {
            self.events.push(*event);
        }
        fn wants_broadcasts(&self) -> bool {
            true
        }
    }

    let opts = RunOptions {
        iterations: 10,
        eval_every: 2,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let run = |driver| {
        let mut spy = Spy::default();
        session(
            ProblemKind::LinReg,
            driver,
            TopologyKind::Line,
            CompressorConfig::Stochastic(QuantConfig::default()),
            opts.clone(),
        )
        .run_observed(&mut spy)
        .unwrap();
        spy
    };
    let engine = run(DriverKind::Engine);
    let threaded = run(DriverKind::Threaded);
    assert!(!engine.events.is_empty());
    assert_eq!(
        engine.events, threaded.events,
        "broadcast event streams must be driver-identical"
    );
    assert_eq!(engine.evals, threaded.evals);
    assert_eq!(engine.evals, vec![2, 4, 6, 8, 10]);
}

/// The open-registry proof rides the same guarantee: `logreg` runs
/// bit-for-bit identically on all three drivers (its Newton solves are
/// deterministic, so even the accuracy curve matches exactly).
#[test]
fn logreg_agrees_across_drivers() {
    let opts = RunOptions {
        iterations: 15,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let run = |driver| {
        session(
            ProblemKind::LogReg,
            driver,
            TopologyKind::Line,
            CompressorConfig::FullPrecision,
            opts.clone(),
        )
        .workers(4)
        .run()
        .unwrap()
    };
    let engine = run(DriverKind::Engine);
    let threaded = run(DriverKind::Threaded);
    let sim = run(DriverKind::Sim);
    assert_bit_equal("logreg", &engine, &threaded);
    assert_bit_equal("logreg", &engine, &sim);
    assert!(
        engine.final_value() > 0.85,
        "logreg accuracy {} suspiciously low",
        engine.final_value()
    );
}
