//! The distributed (threads + mailboxes) runtime must be bit-for-bit
//! equivalent to the deterministic engine given the same seed — same
//! models, same quantization decisions, same bits on the wire.

use qgadmm::config::{GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::coordinator::threaded::run_threaded;
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::WorkerSolver;
use qgadmm::net::topology::Topology;

fn world(workers: usize) -> (LinRegDataset, Partition) {
    let spec = LinRegSpec {
        samples: 1_400,
        ..LinRegSpec::default()
    };
    let data = LinRegDataset::synthesize(&spec, 71);
    let partition = Partition::contiguous(data.samples(), workers);
    (data, partition)
}

fn run_pair(quant: Option<QuantConfig>, workers: usize, iters: u64, seed: u64) {
    let (data, partition) = world(workers);
    let rho = 1600.0f32;
    let cfg = GadmmConfig {
        workers,
        rho,
        dual_step: 1.0,
        compressor: quant.into(),
        threads: 0,
    };

    // Deterministic engine.
    let problem = LinRegProblem::new(&data, &partition, rho);
    let mut engine = GadmmEngine::new(cfg.clone(), problem, Topology::line(workers), seed);
    let opts = RunOptions {
        iterations: iters,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let eng_report = engine.run(&opts, |e| e.global_objective());

    // Threaded runtime over the same per-worker solvers.
    let problem = LinRegProblem::new(&data, &partition, rho);
    let solvers: Vec<Box<dyn WorkerSolver>> = problem
        .into_workers()
        .into_iter()
        .map(|w| Box::new(w) as Box<dyn WorkerSolver>)
        .collect();
    let thr_report = run_threaded(&cfg, solvers, &opts, seed, |obj, _| obj).unwrap();

    // Bit-for-bit: final models identical, every recorded objective equal,
    // same bits on the air.
    for p in 0..workers {
        assert_eq!(
            engine.theta_at(p),
            thr_report.thetas[p].as_slice(),
            "theta diverged at position {p}"
        );
    }
    assert_eq!(eng_report.comm.bits, thr_report.comm.bits);
    assert_eq!(eng_report.recorder.points.len(), thr_report.recorder.points.len());
    for (a, b) in eng_report
        .recorder
        .points
        .iter()
        .zip(&thr_report.recorder.points)
    {
        assert_eq!(a.value, b.value, "objective diverged at iteration {}", a.iteration);
    }
}

#[test]
fn quantized_runs_are_bit_identical() {
    run_pair(Some(QuantConfig::default()), 6, 60, 2024);
}

#[test]
fn full_precision_runs_are_bit_identical() {
    run_pair(None, 5, 60, 7);
}

#[test]
fn odd_worker_counts_and_higher_bits() {
    run_pair(
        Some(QuantConfig {
            bits: 4,
            ..QuantConfig::default()
        }),
        7,
        40,
        99,
    );
}
