//! Hierarchical topology equivalence: a `hier:` grouped graph is just
//! another bipartite [`Topology`] to the math, so at small n all three
//! local drivers must produce bit-for-bit identical runs on it — the
//! sim driver additionally carrying the grouped machinery (sharded
//! event queue, grouped restitch) that must not perturb a single bit.
//!
//! Also pins convergence: the hier graph is connected, so Q-GADMM on it
//! reaches the same loss-gap tolerance as the flat chain.

use qgadmm::config::{CompressorConfig, QuantConfig, SimConfig};
use qgadmm::coordinator::engine::RunOptions;
use qgadmm::metrics::report::RunSummary;
use qgadmm::net::topology::TopologyKind;
use qgadmm::runtime::session::{DriverKind, ProblemKind, Session};

const WORKERS: usize = 12;
const SEED: u64 = 4242;

fn hier3() -> TopologyKind {
    TopologyKind::parse("hier:3").expect("hier:3 parses")
}

fn session(
    driver: DriverKind,
    topology: TopologyKind,
    compressor: CompressorConfig,
    opts: RunOptions,
) -> Session {
    let mut s = Session::new(ProblemKind::LinReg)
        .quick(true)
        .workers(WORKERS)
        .seed(SEED)
        .driver(driver)
        .topology(topology)
        .compressor(compressor)
        .options(opts);
    if driver == DriverKind::Sim {
        s = s.sim_config(SimConfig::ideal());
    }
    s
}

fn assert_bit_equal(name: &str, a: &RunSummary, b: &RunSummary) {
    assert_eq!(
        a.recorder.points.len(),
        b.recorder.points.len(),
        "{name}: curve lengths diverged ({} vs {})",
        a.driver,
        b.driver
    );
    for (pa, pb) in a.recorder.points.iter().zip(&b.recorder.points) {
        assert_eq!(pa.iteration, pb.iteration, "{name}: iteration axis diverged");
        assert_eq!(
            pa.value.to_bits(),
            pb.value.to_bits(),
            "{name}: metric diverged at iteration {} ({} vs {})",
            pa.iteration,
            a.driver,
            b.driver
        );
        assert_eq!(pa.bits, pb.bits, "{name}: bit curve diverged at {}", pa.iteration);
    }
    assert_eq!(a.comm.bits, b.comm.bits, "{name}: total bits diverged");
    assert_eq!(
        a.comm.transmissions, b.comm.transmissions,
        "{name}: transmission tallies diverged"
    );
    assert_eq!(a.thetas.len(), b.thetas.len(), "{name}: fleet sizes diverged");
    for (p, (ta, tb)) in a.thetas.iter().zip(&b.thetas).enumerate() {
        assert_eq!(
            ta, tb,
            "{name}: final theta diverged at position {p} ({} vs {})",
            a.driver, b.driver
        );
    }
}

/// 12 workers in 3 groups: engine, threaded, and sim runs are
/// bit-identical for a flat stochastic scheme and for a layered spec
/// (linreg's single `all` block) — the sim's sharded queue and grouped
/// layout change scheduling data structures, never outcomes.
#[test]
fn hier_runs_bit_equal_across_local_drivers() {
    let opts = RunOptions {
        iterations: 40,
        eval_every: 1,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let schemes: Vec<(&str, CompressorConfig)> = vec![
        (
            "stochastic",
            CompressorConfig::Stochastic(QuantConfig::default()),
        ),
        (
            "layers",
            CompressorConfig::parse("layers:all=stochastic@4", QuantConfig::default())
                .expect("layered spec parses"),
        ),
    ];
    for (scheme, compressor) in schemes {
        let name = format!("{scheme} on hier:3");
        let run = |driver| {
            session(driver, hier3(), compressor.clone(), opts.clone())
                .run()
                .unwrap_or_else(|e| panic!("{name}: run failed: {e}"))
        };
        let engine = run(DriverKind::Engine);
        let threaded = run(DriverKind::Threaded);
        let sim = run(DriverKind::Sim);
        assert_bit_equal(&name, &engine, &threaded);
        assert_bit_equal(&name, &engine, &sim);
    }
}

/// The hier graph must converge to the same tolerance as the flat chain:
/// same workload, same stopping rule, both cross the loss-gap target
/// before the iteration cap.
#[test]
fn hier_converges_like_the_flat_chain() {
    const TARGET: f64 = 1e-3;
    let opts = RunOptions {
        iterations: 4_000,
        eval_every: 1,
        stop_below: Some(TARGET),
        stop_above: None,
        ..RunOptions::default()
    };
    let run = |topology: TopologyKind| {
        session(
            DriverKind::Engine,
            topology,
            CompressorConfig::Stochastic(QuantConfig::default()),
            opts.clone(),
        )
        .run()
        .expect("run completes")
    };
    for (name, summary) in [("chain", run(TopologyKind::Line)), ("hier:3", run(hier3()))] {
        assert!(
            summary.final_value() <= TARGET,
            "{name} never reached the {TARGET:.0e} loss-gap target \
             (gap {:.3e} after {} iterations)",
            summary.final_value(),
            summary.iterations_run
        );
        assert!(
            summary.iterations_run < 4_000,
            "{name} only hit the target at the cap"
        );
    }
}
