//! Hot-path microbenchmarks (hand-rolled harness — no criterion offline).
//!
//! Covers every component on the per-iteration path: the stochastic
//! quantizer, the bit-packing codec, the linreg local solve (native and,
//! when artifacts are present, XLA), the MLP local step, and one full
//! engine iteration at paper scale. Run via `cargo bench` or
//! `cargo bench --bench hotpath`.

use qgadmm::config::{GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::GadmmEngine;
use qgadmm::data::images::{ImageDataset, ImageSpec};
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::mlp::{MlpDims, MlpProblem};
use qgadmm::model::{LocalProblem, NeighborCtx};
use qgadmm::net::topology::Topology;
use qgadmm::quant::{bitpack, BitPolicy, StochasticQuantizer};
use qgadmm::util::rng::Rng;
use std::time::Instant;

/// Measure `f` for ~`target_secs`, reporting ns/iter and throughput.
fn bench<F: FnMut()>(name: &str, target_secs: f64, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let mut iters = 1u64;
    // Calibrate.
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.05 || iters > 1 << 28 {
            let per = dt / iters as f64;
            let need = (target_secs / per.max(1e-12)) as u64;
            let n = need.clamp(iters, 1 << 30);
            let t0 = Instant::now();
            for _ in 0..n {
                f();
            }
            let per = t0.elapsed().as_secs_f64() / n as f64;
            println!(
                "{name:<48} {:>12.0} ns/iter  ({:>10.2} kops/s, {} iters)",
                per * 1e9,
                1e-3 / per,
                n
            );
            return per;
        }
        iters *= 2;
    }
}

fn main() {
    println!("== hotpath microbenchmarks ==");
    let mut rng = Rng::seed_from_u64(1);

    // --- quantizer ---------------------------------------------------------
    for d in [6usize, 1024, 109_184] {
        let theta: Vec<f32> = (0..d).map(|_| rng.uniform_f32() - 0.5).collect();
        let mut q = StochasticQuantizer::new(d, BitPolicy::Fixed(2));
        let mut qrng = Rng::seed_from_u64(2);
        let per = bench(&format!("squant_native d={d} b=2"), 0.3, || {
            let msg = q.quantize(&theta, &mut qrng);
            std::hint::black_box(&msg);
        });
        println!(
            "{:<48} {:>12.2} M elems/s",
            format!("  -> throughput d={d}"),
            d as f64 / per / 1e6
        );
    }

    // --- bitpack codec ------------------------------------------------------
    for (d, bits) in [(6usize, 2u8), (109_184, 8)] {
        let levels: Vec<u32> = (0..d).map(|_| rng.below(1 << bits) as u32).collect();
        bench(&format!("bitpack::pack d={d} b={bits}"), 0.2, || {
            std::hint::black_box(bitpack::pack(&levels, bits).unwrap());
        });
        let packed = bitpack::pack(&levels, bits).unwrap();
        bench(&format!("bitpack::unpack d={d} b={bits}"), 0.2, || {
            std::hint::black_box(bitpack::unpack(&packed, bits, d).unwrap());
        });
    }

    // --- linreg local solve -------------------------------------------------
    let data = LinRegDataset::synthesize(
        &LinRegSpec {
            samples: 20_000,
            ..LinRegSpec::default()
        },
        3,
    );
    let partition = Partition::contiguous(data.samples(), 50);
    let mut problem = LinRegProblem::new(&data, &partition, 6400.0);
    let d = problem.dims();
    let lam = vec![0.1f32; d];
    let th = vec![0.2f32; d];
    let ctx = NeighborCtx {
        lambda_left: Some(&lam),
        lambda_right: Some(&lam),
        theta_left: Some(&th),
        theta_right: Some(&th),
        rho: 6400.0,
    };
    let mut out = vec![0.0f32; d];
    bench("linreg local solve (native, d=6)", 0.3, || {
        problem.solve(1, &ctx, &mut out);
        std::hint::black_box(&out);
    });

    if qgadmm::runtime::Runtime::available() {
        let rt = qgadmm::runtime::Runtime::load(qgadmm::runtime::Runtime::default_dir()).unwrap();
        let mut xp =
            qgadmm::runtime::solver::XlaLinRegProblem::new(&rt, &data, &partition).unwrap();
        bench("linreg local solve (XLA/PJRT, d=6)", 0.5, || {
            xp.solve(1, &ctx, &mut out);
            std::hint::black_box(&out);
        });
    } else {
        println!("linreg local solve (XLA)                      SKIPPED (no artifacts)");
    }

    // --- full engine iteration, paper scale (N=50, d=6) ---------------------
    let cfg = GadmmConfig {
        workers: 50,
        rho: 6400.0,
        dual_step: 1.0,
        quant: Some(QuantConfig::default()),
    };
    let problem = LinRegProblem::new(&data, &partition, 6400.0);
    let mut engine = GadmmEngine::new(cfg, problem, Topology::line(50), 5);
    bench("Q-GADMM engine iteration (N=50, d=6)", 0.5, || {
        std::hint::black_box(engine.iterate());
    });

    // --- MLP local step (the Q-SGADMM hot spot) ------------------------------
    let img = ImageDataset::synthesize(
        &ImageSpec {
            train: 1_000,
            test: 100,
            ..ImageSpec::default()
        },
        7,
    );
    let ipart = Partition::contiguous(img.train_len(), 2);
    let mut mlp = MlpProblem::new(&img, &ipart, MlpDims::paper(), 9);
    let dd = mlp.dims();
    let mut theta = mlp.initial_theta(1);
    let zeros = vec![0.0f32; dd];
    let ctx = NeighborCtx {
        lambda_left: None,
        lambda_right: Some(&zeros),
        theta_left: None,
        theta_right: Some(&zeros),
        rho: 20.0,
    };
    let per = bench("MLP local solve (10 Adam steps, batch 100)", 2.0, || {
        mlp.solve(0, &ctx, &mut theta);
        std::hint::black_box(&theta);
    });
    // 10 steps × (fwd 2·B·d + bwd ≈ 2× fwd) ≈ 6·10·100·109184 flops
    let flops = 6.0 * 10.0 * 100.0 * 109_184.0;
    println!(
        "{:<48} {:>12.2} GFLOP/s",
        "  -> MLP local solve arithmetic rate",
        flops / per / 1e9
    );

    // --- large-d quantize + pack pipeline (the Q-SGADMM uplink) -------------
    let mut q = StochasticQuantizer::new(dd, BitPolicy::Fixed(8));
    let mut qrng = Rng::seed_from_u64(11);
    bench("uplink quantize+pack d=109184 b=8", 0.5, || {
        let msg = q.quantize(&theta, &mut qrng);
        std::hint::black_box(msg.encode());
    });
}
