//! Hot-path microbenchmarks (hand-rolled harness — no criterion offline).
//!
//! Covers every component on the per-iteration path: the stochastic
//! quantizer (allocating and allocation-free scratch paths), the
//! bit-packing codec (allocating and caller-buffer paths), the linreg
//! local solve (native and, when artifacts are present, XLA), the MLP
//! local step, one full engine iteration at paper scale, and — the
//! headline — one full Q-GADMM iteration at n = 16 workers, d = 10,000
//! run sequentially vs through the parallel phase executor.
//!
//! Every result is printed *and* recorded to `BENCH_hotpath.json` (repo
//! root when run via `cargo bench` from `rust/`, else the working
//! directory) so the perf trajectory is tracked across PRs:
//!
//! ```text
//! { "bench": "hotpath", "quick": bool,
//!   "ns_per_iter": { "<bench name>": f64, ... },
//!   "parallel_iteration": { "workers": 16, "dims": 10000, "threads": T,
//!     "sequential_ns": f64, "parallel_ns": f64, "speedup": f64 },
//!   "topology_iteration": { "workers": 16, "dims": 10000,
//!     "line_ns": f64, "ring_ns": f64, "ring_over_line": f64 },
//!   "compressor_hotpath": { "dims": 10000,
//!     "stochastic": f64, "topk": f64, "full": f64, "layers": f64 } }
//! ```
//!
//! Run `cargo bench --bench hotpath` (full) or append `-- --quick` for the
//! CI-sized smoke run (same coverage, shorter measurement windows).
//!
//! **Perf gate:** `-- --gate <baseline.json>` loads a committed
//! `BENCH_hotpath.json` *before* benchmarking and, after writing the new
//! trajectory, fails the process if any `ns_per_iter` entry shared with
//! the baseline regressed by more than 15%. Keys on only one side are
//! reported but never gate (benches are added and renamed across PRs),
//! so a fresh/empty baseline passes vacuously and CI refreshes the
//! committed file from the run it just gated.

use qgadmm::config::{CompressorConfig, GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::metrics::{NoopObserver, Observer};
use qgadmm::telemetry::Record;
use qgadmm::data::images::{ImageDataset, ImageSpec};
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::mlp::{MlpDims, MlpProblem};
use qgadmm::model::scale::DiagLinRegProblem;
use qgadmm::model::{BlockLayout, LinkBuf, LocalProblem};
use qgadmm::net::topology::Topology;
use qgadmm::quant::{bitpack, BitPolicy, Compressor, StochasticQuantizer};
use qgadmm::util::json::Json;
use qgadmm::util::rng::Rng;
use std::time::Instant;

/// Collected `(name, ns/iter)` results, flushed to BENCH_hotpath.json.
struct Results {
    quick: bool,
    ns: Vec<(String, f64)>,
}

impl Results {
    /// Measure `f` for ~`target_secs`, print, record, return seconds/iter.
    fn bench<F: FnMut()>(&mut self, name: &str, target_secs: f64, mut f: F) -> f64 {
        let target_secs = if self.quick {
            (target_secs * 0.1).max(0.02)
        } else {
            target_secs
        };
        // Warmup.
        for _ in 0..3 {
            f();
        }
        let mut iters = 1u64;
        // Calibrate.
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed().as_secs_f64();
            if dt > 0.05 || iters > 1 << 28 {
                let per = dt / iters as f64;
                let need = (target_secs / per.max(1e-12)) as u64;
                let n = need.clamp(iters, 1 << 30);
                let t0 = Instant::now();
                for _ in 0..n {
                    f();
                }
                let per = t0.elapsed().as_secs_f64() / n as f64;
                println!(
                    "{name:<48} {:>12.0} ns/iter  ({:>10.2} kops/s, {} iters)",
                    per * 1e9,
                    1e-3 / per,
                    n
                );
                self.ns.push((name.to_string(), per * 1e9));
                return per;
            }
            iters *= 2;
        }
    }

    fn flush(&self, parallel: Json, topology: Json, compressor: Json, telemetry: Json) {
        let mut ns = Json::obj();
        for (name, v) in &self.ns {
            ns.set(name, Json::Num(*v));
        }
        let mut doc = Json::obj();
        doc.set("bench", Json::Str("hotpath".to_string()));
        doc.set("quick", Json::Bool(self.quick));
        doc.set("ns_per_iter", ns);
        doc.set("parallel_iteration", parallel);
        doc.set("topology_iteration", topology);
        doc.set("compressor_hotpath", compressor);
        doc.set("telemetry_overhead", telemetry);
        // `cargo bench` runs with cwd = the package root (rust/); the
        // trajectory file lives at the repository root next to ROADMAP.md.
        let path = if std::path::Path::new("../ROADMAP.md").exists() {
            "../BENCH_hotpath.json"
        } else {
            "BENCH_hotpath.json"
        };
        match std::fs::write(path, doc.to_string_pretty()) {
            Ok(()) => println!("\nresults written to {path}"),
            Err(e) => {
                // The JSON *is* the deliverable (per-PR perf trajectory) —
                // a silent write failure must fail the bench-smoke CI job.
                eprintln!("\nfailed to write {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

/// Maximum tolerated slowdown per shared `ns_per_iter` key before the
/// gate fails: 15% — wide enough for shared-runner noise on the quick
/// windows, tight enough to catch a real hot-path regression.
const GATE_TOLERANCE: f64 = 0.15;

/// Compare this run against a committed baseline document. Returns the
/// names that regressed beyond [`GATE_TOLERANCE`].
fn gate_regressions(baseline: &Json, current: &[(String, f64)]) -> Vec<String> {
    let mut regressions = Vec::new();
    let mut shared = 0usize;
    for (name, now_ns) in current {
        let base_ns = baseline
            .get("ns_per_iter")
            .and_then(|ns| ns.get(name))
            .and_then(|v| v.as_f64());
        let Some(base_ns) = base_ns else {
            println!("gate: {name:?} not in baseline (new bench, not gated)");
            continue;
        };
        shared += 1;
        let ratio = now_ns / base_ns.max(1e-12);
        if ratio > 1.0 + GATE_TOLERANCE {
            regressions.push(format!(
                "{name}: {base_ns:.0} ns -> {now_ns:.0} ns ({:+.1}%)",
                (ratio - 1.0) * 100.0
            ));
        }
    }
    if shared == 0 {
        println!(
            "gate: no shared ns_per_iter keys with the baseline — vacuous pass \
             (the trajectory starts from this run)"
        );
    } else {
        println!(
            "gate: {shared} shared keys checked at {:.0}% tolerance, {} regressed",
            GATE_TOLERANCE * 100.0,
            regressions.len()
        );
    }
    regressions
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    // Load the gate baseline BEFORE benchmarking: a missing or malformed
    // baseline must fail fast, not after minutes of measurement.
    let baseline = args
        .iter()
        .position(|a| a == "--gate")
        .map(|i| {
            let path = args.get(i + 1).unwrap_or_else(|| {
                eprintln!("--gate requires a baseline path");
                std::process::exit(1);
            });
            let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
                eprintln!("--gate {path}: cannot read baseline: {e}");
                std::process::exit(1);
            });
            Json::parse(&text).unwrap_or_else(|e| {
                eprintln!("--gate {path}: baseline is not valid JSON: {e:?}");
                std::process::exit(1);
            })
        });
    let mut res = Results {
        quick,
        ns: Vec::new(),
    };
    println!(
        "== hotpath microbenchmarks{} ==",
        if quick { " (quick)" } else { "" }
    );
    let mut rng = Rng::seed_from_u64(1);

    // --- quantizer ---------------------------------------------------------
    for d in [6usize, 1024, 109_184] {
        let theta: Vec<f32> = (0..d).map(|_| rng.uniform_f32() - 0.5).collect();
        let mut q = StochasticQuantizer::new(d, BitPolicy::Fixed(2));
        let mut qrng = Rng::seed_from_u64(2);
        let per = res.bench(&format!("squant_alloc d={d} b=2"), 0.3, || {
            let msg = q.quantize(&theta, &mut qrng);
            std::hint::black_box(&msg);
        });
        println!(
            "{:<48} {:>12.2} M elems/s",
            format!("  -> throughput d={d}"),
            d as f64 / per / 1e6
        );
        // The allocation-free engine path: scratch levels + fused view.
        let mut view = vec![0.0f32; d];
        res.bench(&format!("squant_into d={d} b=2"), 0.3, || {
            let out = q.quantize_into(&theta, &mut qrng, &mut view);
            std::hint::black_box(out);
        });
    }

    // --- bitpack codec ------------------------------------------------------
    for (d, bits) in [(6usize, 2u8), (109_184, 8)] {
        let levels: Vec<u32> = (0..d).map(|_| rng.below(1 << bits) as u32).collect();
        res.bench(&format!("bitpack::pack d={d} b={bits}"), 0.2, || {
            std::hint::black_box(bitpack::pack(&levels, bits).unwrap());
        });
        let mut buf = vec![0u8; bitpack::packed_len(bits, d)];
        res.bench(&format!("bitpack::pack_into d={d} b={bits}"), 0.2, || {
            bitpack::pack_into(&levels, bits, &mut buf).unwrap();
            std::hint::black_box(&buf);
        });
        let packed = bitpack::pack(&levels, bits).unwrap();
        res.bench(&format!("bitpack::unpack d={d} b={bits}"), 0.2, || {
            std::hint::black_box(bitpack::unpack(&packed, bits, d).unwrap());
        });
    }

    // --- linreg local solve -------------------------------------------------
    let data = LinRegDataset::synthesize(
        &LinRegSpec {
            samples: 20_000,
            ..LinRegSpec::default()
        },
        3,
    );
    let partition = Partition::contiguous(data.samples(), 50);
    let mut problem = LinRegProblem::new(&data, &partition, 6400.0);
    let d = problem.dims();
    let lam = vec![0.1f32; d];
    let th = vec![0.2f32; d];
    let ctx_buf = LinkBuf::chain(Some(&lam), Some(&th), Some(&lam), Some(&th));
    let ctx = ctx_buf.ctx(6400.0);
    let mut out = vec![0.0f32; d];
    res.bench("linreg local solve (native, d=6)", 0.3, || {
        problem.solve(1, &ctx, &mut out);
        std::hint::black_box(&out);
    });

    if qgadmm::runtime::Runtime::available() {
        let rt = qgadmm::runtime::Runtime::load(qgadmm::runtime::Runtime::default_dir()).unwrap();
        let mut xp =
            qgadmm::runtime::solver::XlaLinRegProblem::new(&rt, &data, &partition).unwrap();
        res.bench("linreg local solve (XLA/PJRT, d=6)", 0.5, || {
            xp.solve(1, &ctx, &mut out);
            std::hint::black_box(&out);
        });
    } else {
        println!("linreg local solve (XLA)                      SKIPPED (no artifacts)");
    }

    // --- diag-Gram local solve at scale (the d=10k scenario) -----------------
    let scale_d = 10_000usize;
    {
        let mut sp = DiagLinRegProblem::synthesize(scale_d, 16, 5);
        let lam = vec![0.1f32; scale_d];
        let th = vec![0.2f32; scale_d];
        let sbuf = LinkBuf::chain(Some(&lam), Some(&th), Some(&lam), Some(&th));
        let sctx = sbuf.ctx(4.0);
        let mut sout = vec![0.0f32; scale_d];
        res.bench("diag linreg local solve (d=10000)", 0.2, || {
            sp.solve(1, &sctx, &mut sout);
            std::hint::black_box(&sout);
        });
    }

    // --- full engine iteration, paper scale (N=50, d=6) ---------------------
    let cfg = GadmmConfig {
        workers: 50,
        rho: 6400.0,
        dual_step: 1.0,
        compressor: CompressorConfig::Stochastic(QuantConfig::default()),
        threads: 1,
    };
    let problem = LinRegProblem::new(&data, &partition, 6400.0);
    let mut engine = GadmmEngine::new(cfg, problem, Topology::line(50), 5);
    res.bench("Q-GADMM engine iteration (N=50, d=6)", 0.5, || {
        std::hint::black_box(engine.iterate());
    });

    // --- sequential vs parallel iteration (N=16, d=10k) ----------------------
    // The headline number for the phase executor: all 8 heads (then all 8
    // tails) solve + quantize concurrently; bit-for-bit the sequential run.
    let make_engine = |threads: usize| {
        let cfg = GadmmConfig {
            workers: 16,
            rho: 4.0,
            dual_step: 1.0,
            compressor: CompressorConfig::Stochastic(QuantConfig::default()),
            threads,
        };
        let problem = DiagLinRegProblem::synthesize(scale_d, 16, 7);
        GadmmEngine::new(cfg, problem, Topology::line(16), 11)
    };
    let mut seq = make_engine(1);
    let seq_per = res.bench("Q-GADMM iteration seq (N=16, d=10k)", 0.6, || {
        std::hint::black_box(seq.iterate());
    });
    let mut par = make_engine(0);
    // Ask the engine what the auto policy resolves to (cores clamped to
    // the 8 head/tail jobs at N=16) — never hand-duplicate that policy.
    let auto_threads = par.effective_threads();
    let par_per = res.bench(
        &format!("Q-GADMM iteration par x{auto_threads} (N=16, d=10k)"),
        0.6,
        || {
            std::hint::black_box(par.iterate());
        },
    );
    let speedup = seq_per / par_per.max(1e-12);
    println!(
        "{:<48} {:>12.2} x  ({} threads)",
        "  -> parallel phase executor speedup", speedup, auto_threads
    );
    let mut parallel = Json::obj();
    parallel.set("problem", Json::Str("diag_linreg".to_string()));
    parallel.set("workers", Json::Num(16.0));
    parallel.set("dims", Json::Num(scale_d as f64));
    parallel.set("quant_bits", Json::Num(2.0));
    parallel.set("threads", Json::Num(auto_threads as f64));
    parallel.set("sequential_ns", Json::Num(seq_per * 1e9));
    parallel.set("parallel_ns", Json::Num(par_per * 1e9));
    parallel.set("speedup", Json::Num(speedup));

    // --- ring vs line iteration (N=16, d=10k, sequential) --------------------
    // Tracks what the degree-general neighbor context costs on the chain
    // fast path: a ring adds one edge (every position at degree 2), so its
    // per-iteration time should match the line's interior-position cost —
    // any divergence beyond that is LinkBuf/edge-list overhead.
    let mut ring16 = {
        let cfg = GadmmConfig {
            workers: 16,
            rho: 4.0,
            dual_step: 1.0,
            compressor: CompressorConfig::Stochastic(QuantConfig::default()),
            threads: 1,
        };
        let problem = DiagLinRegProblem::synthesize(scale_d, 16, 7);
        GadmmEngine::new(cfg, problem, Topology::ring(16).expect("16 is even"), 11)
    };
    let ring_per = res.bench("Q-GADMM iteration ring (N=16, d=10k)", 0.6, || {
        std::hint::black_box(ring16.iterate());
    });
    println!(
        "{:<48} {:>12.3} x  (ring/line, seq)",
        "  -> degree-general context overhead",
        ring_per / seq_per.max(1e-12)
    );
    let mut topology = Json::obj();
    topology.set("problem", Json::Str("diag_linreg".to_string()));
    topology.set("workers", Json::Num(16.0));
    topology.set("dims", Json::Num(scale_d as f64));
    topology.set("line_ns", Json::Num(seq_per * 1e9));
    topology.set("ring_ns", Json::Num(ring_per * 1e9));
    topology.set("ring_over_line", Json::Num(ring_per / seq_per.max(1e-12)));

    // --- O(1) topology position lookup (relink/restitch hot path) ------------
    // `position_of` runs once per worker per relink; at 10⁵ workers the
    // old linear scan made every re-stitch O(n²). The inverse-permutation
    // table must keep this flat regardless of fleet size.
    {
        let big = qgadmm::net::hier::HierTopology::build(
            100_000,
            10_000,
            qgadmm::net::hier::InnerKind::Line,
        )
        .expect("hier builds at 100k workers");
        let mut id = 0usize;
        let lookup_per = res.bench("topology_lookup hier n=100k", 0.2, || {
            // Stride coprime to n so lookups sweep the whole id space.
            id = (id + 7_919) % 100_000;
            std::hint::black_box(big.topo.position_of(id));
        });
        topology.set("lookup_ns", Json::Num(lookup_per * 1e9));
    }

    // --- MLP local step (the Q-SGADMM hot spot) ------------------------------
    let img = ImageDataset::synthesize(
        &ImageSpec {
            train: 1_000,
            test: 100,
            ..ImageSpec::default()
        },
        7,
    );
    let ipart = Partition::contiguous(img.train_len(), 2);
    let mut mlp = MlpProblem::new(&img, &ipart, MlpDims::paper(), 9);
    let dd = mlp.dims();
    let mut theta = mlp.initial_theta(1);
    let zeros = vec![0.0f32; dd];
    let mlp_buf = LinkBuf::chain(None, None, Some(&zeros), Some(&zeros));
    let ctx = mlp_buf.ctx(20.0);
    let per = res.bench("MLP local solve (10 Adam steps, batch 100)", 2.0, || {
        mlp.solve(0, &ctx, &mut theta);
        std::hint::black_box(&theta);
    });
    // 10 steps × (fwd 2·B·d + bwd ≈ 2× fwd) ≈ 6·10·100·109184 flops
    let flops = 6.0 * 10.0 * 100.0 * 109_184.0;
    println!(
        "{:<48} {:>12.2} GFLOP/s",
        "  -> MLP local solve arithmetic rate",
        flops / per / 1e9
    );

    if !quick {
        // --- Q-SGADMM iteration seq vs par at the paper's d=109,184 ---------
        let make_dnn_engine = |threads: usize| {
            let cfg = GadmmConfig {
                workers: 4,
                rho: 20.0,
                dual_step: 0.01,
                compressor: CompressorConfig::Stochastic(QuantConfig {
                    bits: 8,
                    ..QuantConfig::default()
                }),
                threads,
            };
            let part = Partition::contiguous(img.train_len(), 4);
            let prob = MlpProblem::new(&img, &part, MlpDims::paper(), 9);
            let init = prob.initial_theta(1);
            let mut eng = GadmmEngine::new(cfg, prob, Topology::line(4), 13);
            eng.set_initial_theta(&init);
            eng
        };
        let mut dseq = make_dnn_engine(1);
        let dseq_per = res.bench("Q-SGADMM iteration seq (N=4, d=109k)", 1.0, || {
            std::hint::black_box(dseq.iterate());
        });
        let mut dpar = make_dnn_engine(0);
        // N=4 ⇒ 2 jobs per phase ⇒ the engine caps itself at 2 threads.
        let dnn_threads = dpar.effective_threads();
        let dpar_per = res.bench(
            &format!("Q-SGADMM iteration par x{dnn_threads} (N=4, d=109k)"),
            1.0,
            || {
                std::hint::black_box(dpar.iterate());
            },
        );
        println!(
            "{:<48} {:>12.2} x  ({} threads)",
            "  -> Q-SGADMM parallel speedup",
            dseq_per / dpar_per.max(1e-12),
            dnn_threads
        );
    }

    // --- per-scheme compress_into at d=10k (the pluggable-compressor API) ----
    // One fused compress per scheme on the same vector: how much each
    // payload scheme costs per broadcast on the engine hot path.
    let mut compressor_json = Json::obj();
    {
        let cd = 10_000usize;
        let ctheta: Vec<f32> = (0..cd).map(|i| ((i as f32) * 0.37).sin()).collect();
        let mut cview = vec![0.0f32; cd];
        for (label, ccfg) in [
            (
                "stochastic b=2",
                CompressorConfig::Stochastic(QuantConfig::default()),
            ),
            ("topk f=0.01", CompressorConfig::TopK { frac: 0.01 }),
            ("full", CompressorConfig::FullPrecision),
        ] {
            let mut comp = ccfg.build(cd);
            let mut crng = Rng::seed_from_u64(17);
            let per = res.bench(&format!("compress_into {label} d=10k"), 0.3, || {
                let out = comp.compress_into(&ctheta, &mut crng, &mut cview);
                std::hint::black_box(out);
            });
            compressor_json.set(ccfg.name(), Json::Num(per * 1e9));
        }
        // The layer-wise composition on the same 10k vector, split into
        // three blocks of MLP-like proportion (wide input, mid, narrow
        // head): per-block mirrors + sub-payload assembly on top of the
        // flat schemes above.
        let layout = BlockLayout::new(vec![("w1", 8_000), ("w2", 1_500), ("w3", 500)]);
        let lcfg = CompressorConfig::parse(
            "layers:w1=stochastic@4,w2=stochastic@8,w3=full",
            QuantConfig::default(),
        )
        .expect("bench layered spec parses");
        lcfg.validate_blocks(&layout).expect("spec fits the layout");
        let mut lcomp = lcfg.build_for(&layout);
        let mut lrng = Rng::seed_from_u64(17);
        let per = res.bench("compress_into layers 3 blocks d=10k", 0.3, || {
            let out = lcomp.compress_into(&ctheta, &mut lrng, &mut cview);
            std::hint::black_box(out);
        });
        compressor_json.set(lcfg.name(), Json::Num(per * 1e9));
        compressor_json.set("dims", Json::Num(cd as f64));
    }

    // --- large-d quantize + pack pipeline (the Q-SGADMM uplink) -------------
    let mut q = StochasticQuantizer::new(dd, BitPolicy::Fixed(8));
    let mut qrng = Rng::seed_from_u64(11);
    res.bench("uplink quantize+pack d=109184 b=8", 0.5, || {
        let msg = q.quantize(&theta, &mut qrng);
        std::hint::black_box(msg.encode());
    });
    // Allocation-free uplink: scratch quantize + caller-buffer encode.
    let mut view = vec![0.0f32; dd];
    let mut frame = Vec::new();
    res.bench("uplink quantize_into+encode_into d=109184 b=8", 0.5, || {
        let (bits, radius) = q.quantize_into(&theta, &mut qrng, &mut view);
        bitpack::encode_levels_into(bits, radius, q.last_levels(), &mut frame);
        std::hint::black_box(&frame);
    });

    // --- telemetry overhead (sink off vs on, same engine iteration) ----------
    // The zero-cost-when-disabled claim, measured: one observed iteration
    // with the default NoopObserver (sink stays Off — a single branch per
    // would-be record) vs an observer that opts into the full structured
    // stream. Both sides pay the same RunSummary assembly, so the delta
    // is the sink itself.
    struct DrainTelemetry;
    impl Observer for DrainTelemetry {
        fn on_record(&mut self, record: &Record) {
            std::hint::black_box(record);
        }
        fn wants_telemetry(&self) -> bool {
            true
        }
    }
    let tel_opts = RunOptions {
        iterations: 1,
        eval_every: 1_000_000,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let metric = |_: &GadmmEngine<LinRegProblem>| 0.0f64;
    let off_per = res.bench("observed iteration telemetry off (N=50, d=6)", 0.4, || {
        let s = engine.run_observed(&tel_opts, metric, &mut NoopObserver);
        std::hint::black_box(s.iterations_run);
    });
    let mut drain = DrainTelemetry;
    let on_per = res.bench("observed iteration telemetry on (N=50, d=6)", 0.4, || {
        let s = engine.run_observed(&tel_opts, metric, &mut drain);
        std::hint::black_box(s.iterations_run);
    });
    println!(
        "{:<48} {:>12.3} x  (enabled/disabled)",
        "  -> telemetry sink overhead",
        on_per / off_per.max(1e-12)
    );
    let mut telemetry_json = Json::obj();
    telemetry_json.set("off_ns", Json::Num(off_per * 1e9));
    telemetry_json.set("on_ns", Json::Num(on_per * 1e9));
    telemetry_json.set("on_over_off", Json::Num(on_per / off_per.max(1e-12)));

    res.flush(parallel, topology, compressor_json, telemetry_json);

    if let Some(baseline) = baseline {
        let regressions = gate_regressions(&baseline, &res.ns);
        if !regressions.is_empty() {
            eprintln!("\nPERF GATE FAILED (> {:.0}% slower):", GATE_TOLERANCE * 100.0);
            for r in &regressions {
                eprintln!("  {r}");
            }
            std::process::exit(1);
        }
        println!("perf gate passed");
    }
}
