//! End-to-end benchmark: one target per paper figure. Each target runs a
//! reduced-scale slice of the figure's workload and reports wall time plus
//! the figure's headline quantity, so regressions in any layer show up in
//! `cargo bench` output. Full-scale regeneration: `qgadmm figures`.

use qgadmm::baselines::adiana::{run_adiana_linreg, AdianaOptions};
use qgadmm::baselines::gd::{run_gd_linreg, GdOptions};
use qgadmm::baselines::sgd::{run_sgd_images, SgdOptions};
use qgadmm::baselines::QuantMode;
use qgadmm::config::{CompressorConfig, ExperimentConfig, GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::data::images::{ImageDataset, ImageSpec};
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::figures::helpers::{self, LinregWorld};
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::mlp::{MlpDims, MlpProblem};
use qgadmm::net::topology::Topology;
use std::time::Instant;

fn timed(name: &str, f: impl FnOnce() -> String) {
    let t0 = Instant::now();
    let detail = f();
    println!("{name:<28} {:>9.3} s   {detail}", t0.elapsed().as_secs_f64());
}

fn main() {
    println!("== figure end-to-end benches (reduced scale; see `qgadmm figures` for full) ==");
    let cfg = ExperimentConfig::default();

    let data = LinRegDataset::synthesize(
        &LinRegSpec {
            samples: 20_000,
            ..LinRegSpec::default()
        },
        1,
    );
    let (_, f_star) = data.optimum();
    let workers = 16;
    let target = 1e-4;

    // fig2: loss-vs-rounds/bits/energy — one run per algorithm.
    timed("fig2 Q-GADMM", || {
        let partition = Partition::contiguous(data.samples(), workers);
        let problem = LinRegProblem::new(&data, &partition, helpers::LINREG_RHO);
        let gcfg = GadmmConfig {
            workers,
            rho: helpers::LINREG_RHO,
            dual_step: 1.0,
            compressor: CompressorConfig::Stochastic(QuantConfig::default()),
            threads: 0,
        };
        let mut eng = GadmmEngine::new(gcfg, problem, Topology::line(workers), 2);
        let opts = RunOptions {
            iterations: 6_000,
            eval_every: 1,
            stop_below: Some(target),
            stop_above: None,
            ..RunOptions::default()
        };
        let rep = eng.run(&opts, |e| (e.global_objective() - f_star).abs());
        format!(
            "iters={} bits={} gap={:.1e}",
            rep.iterations_run,
            rep.comm.bits,
            rep.final_loss_gap()
        )
    });
    timed("fig2 GD baseline", || {
        let rep = run_gd_linreg(
            &data,
            workers,
            &GdOptions {
                iterations: 30_000,
                stop_below: Some(target),
                eval_every: 10,
                ..GdOptions::default()
            },
        );
        format!("iters={} bits={}", rep.iterations_run, rep.comm.bits)
    });
    timed("fig2 QGD baseline", || {
        let rep = run_gd_linreg(
            &data,
            workers,
            &GdOptions {
                iterations: 30_000,
                stop_below: Some(target),
                eval_every: 10,
                quant: Some((QuantConfig::default(), QuantMode::Memory)),
                ..GdOptions::default()
            },
        );
        format!("iters={} bits={}", rep.iterations_run, rep.comm.bits)
    });
    timed("fig2 ADIANA baseline", || {
        let rep = run_adiana_linreg(
            &data,
            workers,
            &AdianaOptions {
                iterations: 30_000,
                stop_below: Some(target),
                eval_every: 10,
                ..AdianaOptions::default()
            },
        );
        format!("iters={} bits={}", rep.iterations_run, rep.comm.bits)
    });

    // fig3/fig5 kernel: energy pricing of one drop (trajectory + repricing).
    timed("fig3 one-drop pricing", || {
        let mut c = cfg.clone();
        c.gadmm.workers = workers;
        let world = LinregWorld::new(&c, 1, 77);
        let rec = helpers::run_gadmm_linreg(
            "q",
            &world,
            &c,
            Some(QuantConfig::default()),
            helpers::LINREG_RHO,
            6_000,
            Some(target),
            3,
        );
        format!(
            "energy_to_target={:?} J",
            rec.energy_to(target).map(|e| format!("{e:.2e}"))
        )
    });

    // fig4/fig8b: DNN iteration cost (Q-SGADMM vs SGADMM vs SGD).
    let img = ImageDataset::synthesize(
        &ImageSpec {
            train: 1_000,
            test: 300,
            ..ImageSpec::default()
        },
        5,
    );
    for (name, quant) in [
        ("fig4 Q-SGADMM 5 iters", Some(QuantConfig { bits: 8, ..QuantConfig::default() })),
        ("fig4 SGADMM 5 iters", None),
    ] {
        let img = img.clone();
        timed(name, move || {
            let partition = Partition::contiguous(img.train_len(), 4);
            let problem = MlpProblem::new(&img, &partition, MlpDims::paper(), 7);
            let init = problem.initial_theta(3);
            let gcfg = GadmmConfig {
                workers: 4,
                rho: helpers::DNN_RHO,
                dual_step: helpers::DNN_ALPHA,
                compressor: quant.into(),
                threads: 0,
            };
            let mut eng = GadmmEngine::new(gcfg, problem, Topology::line(4), 9);
            eng.set_initial_theta(&init);
            let opts = RunOptions {
                iterations: 5,
                eval_every: 5,
                stop_below: None,
                stop_above: None,
                ..RunOptions::default()
            };
            let rep = eng.run(&opts, |e| {
                let thetas: Vec<Vec<f32>> =
                    (0..e.workers()).map(|p| e.theta_at(p).to_vec()).collect();
                e.problem().average_model_accuracy(&thetas)
            });
            format!(
                "acc={:.3} bits={}",
                rep.recorder.last_value().unwrap_or(f64::NAN),
                rep.comm.bits
            )
        });
    }
    timed("fig4 SGD 20 iters", || {
        let rep = run_sgd_images(
            &img,
            4,
            MlpDims::paper(),
            &SgdOptions {
                iterations: 20,
                eval_every: 20,
                ..SgdOptions::default()
            },
        );
        format!("acc={:.3}", rep.final_value())
    });

    // fig6: N-scalability probe at two sizes.
    timed("fig6 N-sweep probe", || {
        let mut out = String::new();
        for n in [8usize, 16] {
            let partition = Partition::contiguous(data.samples(), n);
            let problem = LinRegProblem::new(&data, &partition, helpers::LINREG_RHO);
            let gcfg = GadmmConfig {
                workers: n,
                rho: helpers::LINREG_RHO,
                dual_step: 1.0,
                compressor: CompressorConfig::Stochastic(QuantConfig::default()),
                threads: 0,
            };
            let mut eng = GadmmEngine::new(gcfg, problem, Topology::line(n), 2);
            let opts = RunOptions {
                iterations: 6_000,
                eval_every: 1,
                stop_below: Some(target),
                stop_above: None,
                ..RunOptions::default()
            };
            let rep = eng.run(&opts, |e| (e.global_objective() - f_star).abs());
            out.push_str(&format!(
                "N={n}:bits={:?} ",
                rep.recorder.bits_to(target)
            ));
        }
        out
    });

    // fig7: rho sensitivity probe.
    timed("fig7 rho probe", || {
        let mut out = String::new();
        for rho in [400.0f32, 6400.0] {
            let partition = Partition::contiguous(data.samples(), workers);
            let problem = LinRegProblem::new(&data, &partition, rho);
            let gcfg = GadmmConfig {
                workers,
                rho,
                dual_step: 1.0,
                compressor: CompressorConfig::Stochastic(QuantConfig::default()),
                threads: 0,
            };
            let mut eng = GadmmEngine::new(gcfg, problem, Topology::line(workers), 2);
            let opts = RunOptions {
                iterations: 4_000,
                eval_every: 1,
                stop_below: Some(target),
                stop_above: None,
                ..RunOptions::default()
            };
            let rep = eng.run(&opts, |e| (e.global_objective() - f_star).abs());
            out.push_str(&format!("rho={rho}:iters={} ", rep.iterations_run));
        }
        out
    });

    println!("(fig8 timing curves come from the engine's compute stopwatch; see `qgadmm figures --fig fig8`)");
}
