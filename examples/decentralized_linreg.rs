//! Wireless decentralized learning scenario (the paper's Sec. V-A setup):
//! 50 workers dropped in a 250×250 m² area, chain built with the
//! nearest-neighbor heuristic, Shannon-model energy accounting, and a
//! head-to-head of Q-GADMM vs GADMM vs the PS baselines (GD/QGD/ADIANA).
//!
//! Run: `cargo run --release --example decentralized_linreg`

use qgadmm::config::ExperimentConfig;
use qgadmm::figures::helpers::{q2, run_gadmm_linreg, run_ps_linreg, LinregWorld, LINREG_RHO};

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.gadmm.workers = 20; // laptop-sized slice of the paper's N = 50
    let target = 1e-4;
    let world = LinregWorld::new(&cfg, 1, 99);
    println!(
        "deployed {} workers; chain length {:.0} m; PS candidate at min-sum-distance",
        cfg.gadmm.workers,
        world.topo.total_length(&world.points)
    );

    let mut rows = Vec::new();
    for (name, quant) in [("Q-GADMM-2bits", q2()), ("GADMM", None)] {
        let rec = run_gadmm_linreg(name, &world, &cfg, quant, LINREG_RHO, 8_000, Some(target), 5);
        rows.push((name.to_string(), rec));
    }
    for algo in ["GD", "QGD", "ADIANA"] {
        let rec = run_ps_linreg(algo, &world, &cfg, 40_000, Some(target), 5);
        rows.push((algo.to_string(), rec));
    }

    println!(
        "\n{:<16} {:>10} {:>16} {:>14}",
        "algorithm", "iters", "bits-to-1e-4", "energy (J)"
    );
    for (name, rec) in &rows {
        let hit = rec.first_below(target);
        println!(
            "{:<16} {:>10} {:>16} {:>14}",
            name,
            hit.map(|p| p.iteration.to_string())
                .unwrap_or_else(|| "-".into()),
            hit.map(|p| p.bits.to_string()).unwrap_or_else(|| "-".into()),
            hit.map(|p| format!("{:.3e}", p.energy_joules))
                .unwrap_or_else(|| "-".into()),
        );
    }
    if let (Some(q), Some(g)) = (
        rows[0].1.first_below(target),
        rows[1].1.first_below(target),
    ) {
        println!(
            "\nQ-GADMM vs GADMM: {:.2}x fewer bits, {:.2}x less energy (paper: ~3.5x bits)",
            g.bits as f64 / q.bits as f64,
            g.energy_joules / q.energy_joules
        );
    }
}
