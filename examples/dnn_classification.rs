//! End-to-end driver: decentralized training of the paper's DNN
//! (bias-free MLP 784-128-64-10, exactly d = 109,184 parameters) with
//! **Q-SGADMM** on a real small workload — a 10-class 28×28 image corpus —
//! for a few hundred rounds, logging the loss and accuracy curves and the
//! communication ledger. This is the full-system proof: L3 scheduler +
//! stochastic quantizer + bit-exact wire accounting + DNN local solves
//! (10 Adam steps on the augmented Lagrangian per worker per round).
//!
//! Run:  cargo run --release --example dnn_classification
//! Args: [rounds] [workers] (defaults 150, 10)
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use qgadmm::config::{CompressorConfig, GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::data::images::{ImageDataset, ImageSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::mlp::{MlpDims, MlpProblem};
use qgadmm::net::topology::Topology;
use std::io::Write as _;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let rounds: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(10);

    let spec = ImageSpec {
        train: 6_000,
        test: 2_000,
        ..ImageSpec::default()
    };
    println!(
        "synthesizing {} train / {} test images (10 classes, 28x28)...",
        spec.train, spec.test
    );
    let data = ImageDataset::synthesize(&spec, 2026);
    let partition = Partition::contiguous(data.train_len(), workers);

    let cfg = GadmmConfig {
        workers,
        rho: 20.0,       // paper Sec. V-B
        dual_step: 0.01, // α damping for the non-convex dual update
        compressor: CompressorConfig::Stochastic(QuantConfig {
            bits: 8, // paper: 8-bit quantizer for the DNN task
            ..QuantConfig::default()
        }),
        threads: 0,
    };
    let problem = MlpProblem::new(&data, &partition, MlpDims::paper(), 11);
    let init = problem.initial_theta(13);
    let mut engine = GadmmEngine::new(cfg, problem, Topology::line(workers), 17);
    engine.set_initial_theta(&init);

    println!(
        "training Q-SGADMM: {} workers x {} rounds, d = {}, minibatch 100, 10 Adam steps/round",
        workers,
        rounds,
        MlpDims::paper().dims()
    );
    let t0 = std::time::Instant::now();
    let opts = RunOptions {
        iterations: rounds,
        eval_every: 5,
        stop_below: None,
        stop_above: None,
        ..RunOptions::default()
    };
    let report = engine.run(&opts, |eng| {
        let thetas: Vec<Vec<f32>> = (0..eng.workers())
            .map(|p| eng.theta_at(p).to_vec())
            .collect();
        let acc = eng.problem().average_model_accuracy(&thetas);
        let loss: f64 = (0..eng.workers()).map(|p| eng.local_objective_at(p)).sum();
        println!(
            "round {:>4}  train-CE {:>9.4}  test-acc {:>6.3}  bits {:>13}  compute {:>7.1}s",
            eng.iteration(),
            loss / 6_000.0,
            acc,
            eng.comm().bits,
            eng.compute_secs()
        );
        acc
    });

    let wall = t0.elapsed().as_secs_f64();
    let final_acc = report.recorder.last_value().unwrap_or(f64::NAN);
    let d = MlpDims::paper().dims() as u64;
    let full_precision_bits = report.comm.transmissions * 32 * d;
    println!("\n=== end-to-end summary ===");
    println!("rounds:            {}", report.iterations_run);
    println!("final test acc:    {final_acc:.4}");
    println!("wall time:         {wall:.1} s");
    println!("bits transmitted:  {}", report.comm.bits);
    println!(
        "vs full precision: {} ({:.2}x saved by 8-bit quantization)",
        full_precision_bits,
        full_precision_bits as f64 / report.comm.bits as f64
    );

    // Persist the curve for EXPERIMENTS.md.
    std::fs::create_dir_all("results/e2e_dnn")?;
    let mut f = std::fs::File::create("results/e2e_dnn/qsgadmm_curve.csv")?;
    f.write_all(report.recorder.to_csv().as_bytes())?;
    println!("curve written to results/e2e_dnn/qsgadmm_curve.csv");
    Ok(())
}
