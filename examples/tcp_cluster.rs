//! A real TCP cluster on loopback — four workers, real sockets, and a
//! mid-run crash: worker 2's sockets break at iteration 15 with no
//! announcement, the survivors detect the dead connections, agree on a
//! re-stitch boundary through the shared membership layer (the same one
//! the network simulator uses), resync their mirrors over the shrunken
//! chain, and keep converging.
//!
//! Run: `cargo run --release --example tcp_cluster`
//! (set QGADMM_QUICK=1 for a CI-sized dataset)

use qgadmm::coordinator::engine::RunOptions;
use qgadmm::prelude::*;

/// Counts the membership protocol's telemetry narrative as it streams
/// out of the run.
#[derive(Default)]
struct ProtocolWatch {
    disconnects: Vec<(usize, usize)>,
    resyncs: usize,
    restitch: Option<(u64, usize)>,
}

impl Observer for ProtocolWatch {
    fn on_record(&mut self, record: &Record) {
        match &record.event {
            TraceEvent::Disconnected { worker, peer, .. } => {
                self.disconnects.push((*worker, *peer));
            }
            TraceEvent::Resync { .. } => self.resyncs += 1,
            TraceEvent::Restitch {
                iteration,
                survivors,
            } => self.restitch = Some((*iteration, *survivors)),
            _ => {}
        }
    }

    fn wants_telemetry(&self) -> bool {
        true
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QGADMM_QUICK").map(|v| v == "1").unwrap_or(false);
    let workers = 4;
    let victim = 2;
    let crash_at = 15;

    println!("bringing up a {workers}-worker TCP cluster on loopback...");
    println!("worker {victim}'s sockets will break at iteration {crash_at} (unannounced)\n");

    let mut sim = SimConfig::ideal();
    sim.dropouts = vec![Dropout {
        worker: victim,
        at_iteration: crash_at,
    }];

    let mut watch = ProtocolWatch::default();
    let summary = Session::new(ProblemKind::LinReg)
        .quick(quick)
        .workers(workers)
        .seed(17)
        .driver(DriverKind::Tcp)
        .sim_config(sim)
        .tcp_config(TcpConfig {
            // Detected mode: no worker is told about the schedule —
            // survivors learn of the crash from their broken sockets.
            fault_mode: TcpFaultMode::Detected,
            ..TcpConfig::default()
        })
        .options(RunOptions {
            iterations: if quick { 40 } else { 80 },
            eval_every: 1,
            stop_below: None,
            stop_above: None,
            ..RunOptions::default()
        })
        .run_observed(&mut watch)?;

    for (w, p) in &watch.disconnects {
        println!("worker {w} detected worker {p}'s connection drop");
    }
    if let Some((k, survivors)) = watch.restitch {
        println!(
            "membership re-stitched the chain at iteration {k}: {survivors} survivors, \
             {} mirror resyncs\n",
            watch.resyncs
        );
    } else {
        println!("(telemetry feature disabled — protocol events not traced)\n");
    }

    for point in summary.recorder.thinned(10).points {
        println!(
            "iter {:>4}  |F - F*| = {:>12.5e}  cumulative bits {}",
            point.iteration, point.value, point.bits
        );
    }
    println!(
        "\nfinal gap {:.3e} with {} surviving workers after {} iterations over real sockets \
         ({} transmissions, {} bits, {:.2}s wall)",
        summary.final_value(),
        summary.thetas.len(),
        summary.iterations_run,
        summary.comm.transmissions,
        summary.comm.bits,
        summary.wall_secs,
    );
    anyhow::ensure!(
        summary.thetas.len() == workers - 1,
        "expected the fleet to shrink by exactly the crashed worker"
    );
    anyhow::ensure!(summary.final_value().is_finite(), "run diverged");
    Ok(())
}
