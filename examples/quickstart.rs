//! Quickstart: decentralized linear regression with Q-GADMM through the
//! unified Session API in ~20 lines.
//!
//! One `Session` picks the four orthogonal axes — problem, compressor,
//! topology, driver — and every driver returns the same `RunSummary`.
//! Swap `DriverKind::Engine` for `Threaded` (one OS thread per worker)
//! or `Sim` (discrete-event network simulator) and nothing else changes.
//!
//! Run: `cargo run --release --example quickstart`
//! (set QGADMM_QUICK=1 for the CI-sized dataset; set QGADMM_TRACE and/or
//! QGADMM_CHROME_TRACE to a path to export the structured telemetry
//! stream — the Chrome file loads in chrome://tracing or Perfetto)

use qgadmm::prelude::*;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("QGADMM_QUICK").is_ok();

    // Optional structured tracing: iteration/phase spans and per-link
    // compress outcomes, exported after the run.
    let mut telemetry = TelemetryOptions::off();
    if let Ok(path) = std::env::var("QGADMM_TRACE") {
        telemetry = telemetry.with_jsonl(path);
    }
    if let Ok(path) = std::env::var("QGADMM_CHROME_TRACE") {
        telemetry = telemetry.with_chrome(path);
    }

    // Q-GADMM = GADMM + 2-bit stochastic quantization (the default
    // compressor). Ten workers on a chain, loss-gap metric with early
    // stop at the 1e-4 target.
    let summary = Session::new(ProblemKind::LinReg)
        .workers(10)
        .driver(DriverKind::Engine)
        .rho(6400.0)
        .iterations(if quick { 400 } else { 5_000 })
        .quick(quick)
        .seed(7)
        .telemetry(telemetry.clone())
        .run()?;

    for p in summary.recorder.thinned(12).points {
        println!(
            "iter {:>5}  |F - F*| = {:>12.5e}   bits sent = {}",
            p.iteration, p.value, p.bits
        );
    }
    println!(
        "\n{} driver finished: {} iterations, final gap {:.3e}, {} bits \
         ({} broadcasts, every one quantized to 2 bits/coordinate + 64)",
        summary.driver,
        summary.iterations_run,
        summary.final_value(),
        summary.comm.bits,
        summary.comm.transmissions,
    );
    if let Some(path) = &telemetry.jsonl {
        println!("telemetry trace written to {}", path.display());
    }
    if let Some(path) = &telemetry.chrome {
        println!("chrome trace written to {} (open in chrome://tracing)", path.display());
    }
    Ok(())
}
