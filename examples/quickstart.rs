//! Quickstart: decentralized linear regression with Q-GADMM in ~40 lines.
//!
//! Ten workers on a chain, 2-bit stochastic quantization, loss-gap curve
//! printed as it converges to the centralized optimum.
//!
//! Run: `cargo run --release --example quickstart`

use qgadmm::config::{CompressorConfig, GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::net::topology::Topology;

fn main() {
    // 1. Data: a 20k×6 regression set, uniformly sharded over 10 workers.
    let data = LinRegDataset::synthesize(&LinRegSpec::default(), 42);
    let (_, f_star) = data.optimum(); // centralized optimum for the metric
    let workers = 10;
    let partition = Partition::contiguous(data.samples(), workers);

    // 2. Algorithm: Q-GADMM = GADMM + 2-bit stochastic quantization.
    //    (Other per-link schemes: CompressorConfig::FullPrecision,
    //    Censored { .. }, TopK { .. } — see the README's "Compression
    //    schemes" section.)
    let cfg = GadmmConfig {
        workers,
        rho: 6400.0,
        dual_step: 1.0,
        compressor: CompressorConfig::Stochastic(QuantConfig::default()),
        threads: 0,
    };
    let problem = LinRegProblem::new(&data, &partition, cfg.rho);
    let mut engine = GadmmEngine::new(cfg, problem, Topology::line(workers), 7);

    // 3. Train until the decentralized objective matches F* to 1e-4.
    let opts = RunOptions {
        iterations: 5_000,
        eval_every: 1,
        stop_below: Some(1e-4),
        stop_above: None,
    };
    let report = engine.run(&opts, |eng| (eng.global_objective() - f_star).abs());

    for p in report.recorder.thinned(12).points {
        println!(
            "iter {:>5}  |F - F*| = {:>12.5e}   bits sent = {}",
            p.iteration, p.value, p.bits
        );
    }
    println!(
        "\nconverged in {} iterations — every broadcast was {} bits instead of {} (32-bit)",
        report.iterations_run,
        2 * data.features() + 64,
        32 * data.features(),
    );
}
