//! The three-layer AOT pipeline end-to-end: L3 Rust engine driving
//! per-worker local solves that execute the L2 JAX graph (with its L1
//! Pallas kernels) through the PJRT CPU client — Python never runs.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example xla_pipeline

use qgadmm::config::{CompressorConfig, GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::{GadmmEngine, RunOptions};
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::net::topology::Topology;
use qgadmm::runtime::solver::{XlaLinRegProblem, XlaQuantizer};
use qgadmm::runtime::Runtime;
use qgadmm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    if !Runtime::available() {
        eprintln!(
            "no artifacts at {:?} — run `make artifacts` first",
            Runtime::default_dir()
        );
        return Ok(());
    }
    let rt = Runtime::load(Runtime::default_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // L1 demo: the Pallas stochastic-quantizer kernel, straight from Rust.
    let d = 6;
    let xq = XlaQuantizer::new(&rt, d, 2)?;
    let mut rng = Rng::seed_from_u64(5);
    let theta: Vec<f32> = (0..d).map(|_| rng.uniform_f32() * 2.0 - 1.0).collect();
    let hat = vec![0.0f32; d];
    let uniforms: Vec<f32> = (0..d).map(|_| rng.uniform_f32()).collect();
    let (levels, hat_new, radius) = xq.quantize(&theta, &hat, &uniforms)?;
    println!("squant kernel: θ = {theta:?}");
    println!("  -> R = {radius:.4}, levels = {levels:?}");
    println!("  -> θ̂  = {hat_new:?}");

    // L2+L3 demo: full Q-GADMM training with every local solve on PJRT.
    let workers = 8;
    let data = LinRegDataset::synthesize(&LinRegSpec::default(), 9);
    let (_, f_star) = data.optimum();
    let partition = Partition::contiguous(data.samples(), workers);
    let problem = XlaLinRegProblem::new(&rt, &data, &partition)?;
    let cfg = GadmmConfig {
        workers,
        rho: 6400.0,
        dual_step: 1.0,
        compressor: CompressorConfig::Stochastic(QuantConfig::default()),
        threads: 0,
    };
    let mut engine = GadmmEngine::new(cfg, problem, Topology::line(workers), 3);
    let opts = RunOptions {
        iterations: 3_000,
        eval_every: 1,
        stop_below: Some(1e-3),
        stop_above: None,
        ..RunOptions::default()
    };
    let t0 = std::time::Instant::now();
    let report = engine.run(&opts, |e| (e.global_objective() - f_star).abs());
    println!(
        "\nQ-GADMM over PJRT: {} iterations to gap {:.3e} in {:.2}s \
         ({} artifact executions)",
        report.iterations_run,
        report.final_loss_gap(),
        t0.elapsed().as_secs_f64(),
        report.iterations_run * workers as u64,
    );
    Ok(())
}
