//! The distributed runtime in action: one OS thread per worker, quantized
//! neighbor messages over in-process mailboxes — the same protocol a
//! network deployment would run, and bit-for-bit identical to the
//! deterministic engine (see tests/threaded_equivalence.rs).
//!
//! Run: `cargo run --release --example distributed_runtime`

use qgadmm::config::{CompressorConfig, GadmmConfig, QuantConfig};
use qgadmm::coordinator::engine::RunOptions;
use qgadmm::coordinator::threaded::run_threaded;
use qgadmm::data::linreg::{LinRegDataset, LinRegSpec};
use qgadmm::data::partition::Partition;
use qgadmm::model::linreg::LinRegProblem;
use qgadmm::model::WorkerSolver;

fn main() -> anyhow::Result<()> {
    let workers = 12;
    let data = LinRegDataset::synthesize(&LinRegSpec::default(), 3);
    let (_, f_star) = data.optimum();
    let partition = Partition::contiguous(data.samples(), workers);
    let cfg = GadmmConfig {
        workers,
        rho: 6400.0,
        dual_step: 1.0,
        compressor: CompressorConfig::Stochastic(QuantConfig::default()),
        threads: 0,
    };

    // Split the fleet problem into per-worker solvers and ship each to a
    // thread.
    let solvers: Vec<Box<dyn WorkerSolver>> = LinRegProblem::new(&data, &partition, cfg.rho)
        .into_workers()
        .into_iter()
        .map(|w| Box::new(w) as Box<dyn WorkerSolver>)
        .collect();

    println!("spawning {workers} worker threads (chain topology, 2-bit quantized links)...");
    // RunOptions are honored uniformly across runtimes — including early
    // stopping: the leader latches the fleet the moment the loss gap
    // crosses the target, even though workers pipeline ahead.
    let opts = RunOptions {
        iterations: 2_000,
        eval_every: 1,
        stop_below: Some(1e-4),
        stop_above: None,
        ..RunOptions::default()
    };
    let report = run_threaded(&cfg, solvers, &opts, 21, |objective_sum, _thetas| {
        (objective_sum - f_star).abs()
    })?;

    for p in report.recorder.thinned(10).points {
        println!(
            "iter {:>5}  |F - F*| = {:>12.5e}  cumulative bits {}",
            p.iteration, p.value, p.bits
        );
    }
    println!(
        "\nfinal gap {:.3e} after {} iterations / {} quantized broadcasts ({} bits total)",
        report.recorder.last_value().unwrap(),
        report.iterations_run,
        report.comm.transmissions,
        report.comm.bits
    );
    Ok(())
}
