//! Decentralized learning over an imperfect wireless network: the
//! discrete-event simulator in action. Three scenarios on one deployed
//! chain:
//!
//! 1. **Loss sweep** — GADMM vs Q-GADMM time-to-target as the frame loss
//!    rate grows. Full-precision frames are ~16× longer than 2-bit
//!    quantized ones, so every retransmission costs proportionally more
//!    air time: quantization's lead *widens* with loss.
//! 2. **Bursty loss** — the same marginal loss concentrated in
//!    Gilbert–Elliott bursts, where consecutive stale mirrors compound
//!    the Sec. III error propagation.
//! 3. **Worker dropout** — two workers die mid-run; the chain re-stitches
//!    with the nearest-neighbor heuristic and training continues on the
//!    survivors.
//!
//! Every run returns the unified `RunSummary`; the simulator's extras
//! (link-layer ledger, virtual clock, time-to-target) ride in its
//! `SimExt`.
//!
//! Run: `cargo run --release --example lossy_network`
//! (set QGADMM_QUICK=1 for a CI-sized sweep)

use qgadmm::config::{BurstParams, Dropout, ExperimentConfig, GadmmConfig, QuantConfig, SimConfig};
use qgadmm::coordinator::engine::RunOptions;
use qgadmm::coordinator::simulated::SimulatedGadmm;
use qgadmm::data::partition::Partition;
use qgadmm::figures::helpers::{LinregWorld, LINREG_RHO};
use qgadmm::metrics::report::RunSummary;
use qgadmm::model::linreg::LinRegProblem;

fn run_once(
    world: &LinregWorld,
    cfg: &ExperimentConfig,
    quant: Option<QuantConfig>,
    sim_cfg: SimConfig,
    iterations: u64,
    target: f64,
) -> RunSummary {
    let gcfg = GadmmConfig {
        workers: cfg.gadmm.workers,
        rho: LINREG_RHO,
        dual_step: 1.0,
        compressor: quant.into(),
        threads: 0,
    };
    let partition = Partition::contiguous(world.data.samples(), gcfg.workers);
    let problem = LinRegProblem::new(&world.data, &partition, gcfg.rho);
    let mut sim = SimulatedGadmm::new(
        gcfg,
        sim_cfg,
        problem,
        world.topo.clone(),
        world.points.clone(),
        cfg.seed,
    );
    let opts = RunOptions {
        iterations,
        eval_every: 1,
        stop_below: Some(target),
        stop_above: None,
        ..RunOptions::default()
    };
    let f_star = world.f_star;
    sim.run(&opts, |s| (s.global_objective() - f_star).abs())
}

fn fmt_t(t: Option<f64>) -> String {
    t.map(|t| format!("{t:8.3}s")).unwrap_or_else(|| "   never".into())
}

fn main() {
    let quick = std::env::var("QGADMM_QUICK").is_ok();
    let mut cfg = ExperimentConfig::default();
    cfg.gadmm.workers = if quick { 8 } else { 12 };
    let target = 1e-4;
    let iters = if quick { 2_000 } else { 8_000 };
    let world = LinregWorld::new(&cfg, cfg.seed, cfg.seed ^ 0x4C);
    println!(
        "deployed {} workers; chain length {:.0} m; target loss gap {target:.0e}\n",
        cfg.gadmm.workers,
        world.topo.total_length(&world.points)
    );

    // ---- 1. loss sweep ---------------------------------------------------
    println!("== iid frame loss sweep (time to target) ==");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12}",
        "loss", "GADMM", "Q-GADMM", "retrans(G)", "retrans(Q)"
    );
    let losses: &[f64] = if quick { &[0.0, 0.1] } else { &[0.0, 0.05, 0.1, 0.2] };
    for &loss in losses {
        let mut s = SimConfig::default();
        s.loss = loss;
        let g = run_once(&world, &cfg, None, s.clone(), iters, target);
        let q = run_once(
            &world,
            &cfg,
            Some(QuantConfig::default()),
            s,
            iters,
            target,
        );
        println!(
            "{loss:>6.2} {:>12} {:>12} {:>12} {:>12}",
            fmt_t(g.sim_ext().time_to_target_secs),
            fmt_t(q.sim_ext().time_to_target_secs),
            g.sim_ext().net.retransmissions,
            q.sim_ext().net.retransmissions,
        );
    }

    // ---- 2. bursty loss --------------------------------------------------
    println!("\n== bursty (Gilbert-Elliott) loss at the same marginal rate ==");
    let mut s = SimConfig::default();
    s.loss = 0.02;
    s.burst = Some(BurstParams::default());
    let q = run_once(
        &world,
        &cfg,
        Some(QuantConfig::default()),
        s,
        iters,
        target,
    );
    println!(
        "Q-GADMM bursty: time-to-target {}  retrans {}  stale rounds {}",
        fmt_t(q.sim_ext().time_to_target_secs),
        q.sim_ext().net.retransmissions,
        q.sim_ext().net.abandoned,
    );

    // ---- 3. worker dropout -----------------------------------------------
    println!("\n== worker dropout with chain re-stitching ==");
    let mut s = SimConfig::default();
    s.loss = 0.05;
    s.dropouts = vec![
        Dropout {
            worker: 3,
            at_iteration: 400,
        },
        Dropout {
            worker: cfg.gadmm.workers - 2,
            at_iteration: 900,
        },
    ];
    let q = run_once(
        &world,
        &cfg,
        Some(QuantConfig::default()),
        s,
        iters,
        target,
    );
    // One printing/serialization path with the CLI (RunSummary methods).
    q.print_summary("Q-GADMM+drop");
    println!(
        "Q-GADMM with 2 dropouts: ran {} iterations, {} restitches, final gap {:.3e}, time-to-target {}",
        q.iterations_run,
        q.sim_ext().restitches,
        q.recorder.last_value().unwrap_or(f64::NAN),
        fmt_t(q.sim_ext().time_to_target_secs),
    );
    println!(
        "(the survivor chain optimizes the survivors' objective; the original \
         fleet optimum no longer applies after a dropout)"
    );
}
