"""L1 squant kernel vs the pure-jnp oracle — the core correctness signal
for the quantizer, plus the paper's statistical invariants (unbiasedness,
variance bound, reconstruction identity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import squant_ref
from compile.kernels.squant import squant


def _rand(key, d, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    theta = jax.random.normal(k1, (d,), jnp.float32) * scale
    hat = jax.random.normal(k2, (d,), jnp.float32) * scale
    u = jax.random.uniform(k3, (d,), jnp.float32)
    return theta, hat, u


@settings(max_examples=30, deadline=None)
@given(
    d=st.sampled_from([1, 3, 6, 17, 128, 1000, 8192, 9000]),
    bits=st.sampled_from([1, 2, 3, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref(d, bits, seed):
    theta, hat, u = _rand(jax.random.PRNGKey(seed), d)
    q, hat_new, radius = squant(theta, hat, u, bits)
    q_r, hat_r, radius_r = squant_ref(theta, hat, u, bits)
    assert float(radius) == float(radius_r)
    # XLA fuses the kernel arithmetic differently inside the Pallas
    # interpret loop (FMA contraction), so `c` can differ by ~1 ULP — at a
    # floor/probability boundary that flips the stochastic rounding by one
    # level. Both outcomes are valid unbiased quantizations; require exact
    # agreement except a ≤1-level flip on a tiny fraction of coordinates.
    qn, qr = np.asarray(q), np.asarray(q_r)
    diff = np.abs(qn - qr)
    assert diff.max() <= 1.0, diff.max()
    assert (diff > 0).mean() <= 0.005, (diff > 0).mean()
    delta = 2.0 * float(radius) / ((1 << bits) - 1) if float(radius) > 0 else 0.0
    np.testing.assert_allclose(
        np.asarray(hat_new), np.asarray(hat_r), rtol=1e-6, atol=delta * 1.0001 + 1e-6
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([1, 2, 8]))
def test_levels_in_range(seed, bits):
    theta, hat, u = _rand(jax.random.PRNGKey(seed), 257, scale=5.0)
    q, _, _ = squant(theta, hat, u, bits)
    qn = np.asarray(q)
    assert qn.min() >= 0
    assert qn.max() <= (1 << bits) - 1
    assert np.all(qn == np.floor(qn))


def test_zero_radius_short_circuit():
    theta = jnp.ones((16,), jnp.float32) * 0.5
    q, hat_new, radius = squant(theta, theta, jnp.zeros((16,), jnp.float32), 2)
    assert float(radius) == 0.0
    np.testing.assert_array_equal(np.asarray(q), np.zeros(16))
    np.testing.assert_array_equal(np.asarray(hat_new), np.asarray(theta))


def test_reconstruction_error_bounded_by_delta():
    key = jax.random.PRNGKey(7)
    theta, hat, u = _rand(key, 512, scale=2.0)
    bits = 3
    q, hat_new, radius = squant(theta, hat, u, bits)
    delta = 2.0 * float(radius) / ((1 << bits) - 1)
    err = np.abs(np.asarray(hat_new) - np.asarray(theta))
    assert err.max() <= delta * 1.0001


def test_unbiasedness_statistical():
    # E[theta_hat_new - theta] = 0 over fresh uniforms.
    d = 8
    key = jax.random.PRNGKey(3)
    theta = jax.random.normal(key, (d,), jnp.float32)
    hat = jnp.zeros((d,), jnp.float32)
    trials = 4000
    u = jax.random.uniform(jax.random.PRNGKey(11), (trials, d), jnp.float32)
    total = np.zeros(d)
    bits = 2
    for t in range(trials):
        _, hat_new, radius = squant(theta, hat, u[t], bits)
        total += np.asarray(hat_new) - np.asarray(theta)
    mean_err = total / trials
    delta = 2.0 * float(radius) / 3.0
    # SEM per dim ~ delta/2/sqrt(trials)
    assert np.abs(mean_err).max() < 4.0 * delta / 2.0 / np.sqrt(trials) + 1e-6


def test_variance_bound():
    # E||eps||^2 <= d * delta^2 / 4 (Sec. III-A).
    d = 16
    theta = jax.random.normal(jax.random.PRNGKey(5), (d,), jnp.float32)
    hat = jnp.zeros((d,), jnp.float32)
    bits = 2
    trials = 2000
    u = jax.random.uniform(jax.random.PRNGKey(13), (trials, d), jnp.float32)
    acc = 0.0
    for t in range(trials):
        _, hat_new, radius = squant(theta, hat, u[t], bits)
        acc += float(jnp.sum((hat_new - theta) ** 2))
    delta = 2.0 * float(radius) / 3.0
    assert acc / trials <= d * delta * delta / 4.0 * 1.05


@pytest.mark.parametrize("d", [6, 109184])
def test_paper_dimensions_roundtrip(d):
    theta, hat, u = _rand(jax.random.PRNGKey(d), d)
    bits = 2 if d == 6 else 8
    q, hat_new, radius = squant(theta, hat, u, bits)
    assert q.shape == (d,)
    assert hat_new.shape == (d,)
    # Reconstruction identity (eq. 13): hat_new == hat + delta*q - R.
    delta = 2.0 * float(radius) / ((1 << bits) - 1)
    rec = np.asarray(hat) + delta * np.asarray(q) - float(radius)
    np.testing.assert_allclose(np.asarray(hat_new), rec, rtol=1e-5, atol=1e-5)
