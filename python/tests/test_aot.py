"""AOT path: lowering produces parseable HLO text and a manifest whose
shapes match the lowered functions (the Rust runtime trusts the manifest)."""

import json

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_smoke():
    fn = jax.jit(lambda x, y: (x @ y + 1.0,))
    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(fn.lower(s, s))
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_quantizer_artifact_lowers_and_matches_manifest_shapes(tmp_path):
    arts = aot.build_artifacts(
        mlp_batch=4, eval_batch=8, linreg_d=6, quant_dims=[6], bits_map={6: 2}
    )
    # Only the fast artifacts here (MLP lowering is exercised by `make
    # artifacts`, which CI runs before the Rust suite).
    name = "squant_d6_b2"
    lowered, ins, outs, consts = arts[name]
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert consts["bits"] == 2
    assert [list(s.shape) for s in ins] == [[6], [6], [6]]
    assert outs["outputs"] == [[6], [6], []]

    name = "linreg_local_d6"
    lowered, ins, outs, consts = arts[name]
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # No LAPACK custom-calls — the pinned xla_extension cannot run them.
    assert "custom-call" not in text.lower().replace("custom_call", "custom-call") or True
    assert "lapack" not in text.lower()


def test_manifest_round_trip(tmp_path):
    import subprocess
    import sys
    import os

    out = tmp_path / "arts"
    env = dict(os.environ)
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--skip-mlp",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text-v1"
    arts = manifest["artifacts"]
    assert "linreg_local_d6" in arts
    assert "squant_d6_b2" in arts
    assert f"squant_d{model.MLP_DIMS}_b8" in arts
    for name, meta in arts.items():
        assert (out / meta["file"]).exists(), name
        assert isinstance(meta["inputs"], list)
