"""L2 model graphs: shapes, optimality of the linreg local solve, descent
of the Q-SGADMM local Adam step, and the unrolled Cholesky solver."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import admm_rhs_ref
from compile.kernels.admm_rhs import admm_rhs


def _spd(key, d, jitter=1.0):
    b = jax.random.normal(key, (d, d), jnp.float32)
    return b @ b.T + jitter * jnp.eye(d, dtype=jnp.float32)


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([2, 4, 6, 9]), seed=st.integers(0, 2**31 - 1))
def test_chol_solve_unrolled(d, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    a = _spd(k1, d)
    x_true = jax.random.normal(k2, (d,), jnp.float32)
    rhs = a @ x_true
    x = model.chol_solve_unrolled(a, rhs, d)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_true), rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), masks=st.sampled_from([(1.0, 1.0), (0.0, 1.0), (1.0, 0.0)]))
def test_admm_rhs_kernel_matches_ref(seed, masks):
    d = 6
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    vs = [jax.random.normal(k, (d,), jnp.float32) for k in keys]
    got = admm_rhs(vs[0], vs[1], vs[2], vs[3], vs[4], masks[0], masks[1], 3.5)
    want = admm_rhs_ref(vs[0], vs[1], vs[2], vs[3], vs[4], masks[0], masks[1], 3.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_linreg_local_is_argmin():
    """The solve satisfies the first-order condition of eq. (14)."""
    d = 6
    keys = jax.random.split(jax.random.PRNGKey(2), 6)
    a = _spd(keys[0], d, jitter=2.0)
    b = jax.random.normal(keys[1], (d,), jnp.float32)
    lam_l = jax.random.normal(keys[2], (d,), jnp.float32)
    lam_r = jax.random.normal(keys[3], (d,), jnp.float32)
    th_l = jax.random.normal(keys[4], (d,), jnp.float32)
    th_r = jax.random.normal(keys[5], (d,), jnp.float32)
    rho = 5.0
    theta = model.linreg_local(a, b, lam_l, lam_r, th_l, th_r, 1.0, 1.0, rho)
    # Gradient of the augmented local objective at the solution:
    # A θ − b − λ_l + λ_r + ρ(θ−θ̂_l) + ρ(θ−θ̂_r) = 0
    g = a @ theta - b - lam_l + lam_r + rho * (theta - th_l) + rho * (theta - th_r)
    assert float(jnp.max(jnp.abs(g))) < 1e-2, g


def test_linreg_local_end_worker():
    d = 6
    keys = jax.random.split(jax.random.PRNGKey(4), 4)
    a = _spd(keys[0], d, jitter=2.0)
    b = jax.random.normal(keys[1], (d,), jnp.float32)
    lam_r = jax.random.normal(keys[2], (d,), jnp.float32)
    th_r = jax.random.normal(keys[3], (d,), jnp.float32)
    zeros = jnp.zeros((d,), jnp.float32)
    rho = 2.0
    theta = model.linreg_local(a, b, zeros, lam_r, zeros, th_r, 0.0, 1.0, rho)
    g = a @ theta - b + lam_r + rho * (theta - th_r)
    assert float(jnp.max(jnp.abs(g))) < 1e-2


def _tiny_batch(key, batch=8):
    kx, ky = jax.random.split(key)
    x = jax.random.uniform(kx, (batch, model.MLP_IN), jnp.float32)
    labels = jax.random.randint(ky, (batch,), 0, model.MLP_OUT)
    y = jax.nn.one_hot(labels, model.MLP_OUT, dtype=jnp.float32)
    return x, y


def _init_theta(key):
    t = jax.random.normal(key, (model.MLP_DIMS,), jnp.float32)
    return t * 0.03


def test_mlp_dims_constant():
    assert model.MLP_DIMS == 109_184


def test_mlp_grad_matches_finite_difference():
    key = jax.random.PRNGKey(5)
    theta = _init_theta(key)
    x, y = _tiny_batch(jax.random.PRNGKey(6))
    g = model.mlp_grad(theta, x, y)
    assert g.shape == (model.MLP_DIMS,)
    # Probe a few coordinates with central differences.
    eps = 1e-2
    for idx in [0, 1234, 100_352 + 17, 109_183]:
        e = jnp.zeros_like(theta).at[idx].set(eps)
        lp = model.mlp_ce_loss(theta + e, x, y)
        lm = model.mlp_ce_loss(theta - e, x, y)
        fd = (float(lp) - float(lm)) / (2 * eps)
        assert abs(fd - float(g[idx])) < 5e-2 * (1 + abs(fd)), (idx, fd, float(g[idx]))


def test_mlp_local_adam_descends():
    key = jax.random.PRNGKey(7)
    theta = _init_theta(key)
    x, y = _tiny_batch(jax.random.PRNGKey(8), batch=16)
    d = model.MLP_DIMS
    zeros = jnp.zeros((d,), jnp.float32)

    def aug(t):
        return float(model.mlp_ce_loss(t, x, y))

    before = aug(theta)
    out = model.mlp_local_adam(theta, x, y, zeros, zeros, zeros, zeros, 0.0, 0.0, 0.0)
    after = aug(out)
    assert after < before, (before, after)


def test_mlp_local_adam_penalty_pulls_towards_neighbors():
    # With a huge rho and no data signal... data always present; instead:
    # verify the penalty reduces disagreement vs the no-penalty update.
    key = jax.random.PRNGKey(9)
    theta = _init_theta(key)
    x, y = _tiny_batch(jax.random.PRNGKey(10), batch=8)
    d = model.MLP_DIMS
    zeros = jnp.zeros((d,), jnp.float32)
    target = _init_theta(jax.random.PRNGKey(11))
    free = model.mlp_local_adam(theta, x, y, zeros, zeros, zeros, zeros, 0.0, 0.0, 0.0)
    pulled = model.mlp_local_adam(theta, x, y, zeros, zeros, target, target, 1.0, 1.0, 50.0)
    dist_free = float(jnp.linalg.norm(free - target))
    dist_pulled = float(jnp.linalg.norm(pulled - target))
    assert dist_pulled < dist_free


def test_mlp_eval_shapes():
    theta = _init_theta(jax.random.PRNGKey(12))
    x = jnp.ones((256, model.MLP_IN), jnp.float32)
    logits = model.mlp_eval(theta, x)
    assert logits.shape == (256, model.MLP_OUT)
