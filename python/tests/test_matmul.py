"""L1 tiled-matmul kernel vs the jnp oracle, including the custom-vjp
backward path (the Q-SGADMM local step differentiates through it)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import matmul, pallas_matmul
from compile.kernels.ref import matmul_ref


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 7, 64, 100, 130, 256]),
    k=st.sampled_from([1, 10, 64, 128, 300, 784]),
    n=st.sampled_from([1, 10, 64, 128, 130]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    got = matmul(x, w)
    want = matmul_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4
    )


def test_gradients_match_jnp():
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (32, 48), jnp.float32)
    w = jax.random.normal(k2, (48, 24), jnp.float32)
    t = jax.random.normal(k3, (32, 24), jnp.float32)

    def loss_pallas(x, w):
        return jnp.sum((pallas_matmul(x, w) - t) ** 2)

    def loss_ref(x, w):
        return jnp.sum((jnp.dot(x, w) - t) ** 2)

    gx_p, gw_p = jax.grad(loss_pallas, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gw_p), np.asarray(gw_r), rtol=1e-4, atol=1e-3)


def test_mlp_layer_shapes():
    # The exact layer shapes of the paper's MLP all go through cleanly.
    x = jnp.ones((100, 784), jnp.float32)
    w1 = jnp.ones((784, 128), jnp.float32) * 0.01
    w2 = jnp.ones((128, 64), jnp.float32) * 0.01
    w3 = jnp.ones((64, 10), jnp.float32) * 0.01
    h1 = matmul(x, w1)
    h2 = matmul(h1, w2)
    out = matmul(h2, w3)
    assert out.shape == (100, 10)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x @ w1 @ w2 @ w3), rtol=1e-4, atol=1e-3
    )
