"""Pure-jnp oracles for every L1 kernel — the correctness ground truth the
pytest suite checks the Pallas kernels against (and the spec the Rust
native backend mirrors)."""

import jax.numpy as jnp


def squant_ref(theta, theta_hat, u, bits: int):
    """Reference stochastic quantizer (eqs. (6)-(13))."""
    num_levels = jnp.float32((1 << bits) - 1)
    radius = jnp.max(jnp.abs(theta - theta_hat)).astype(jnp.float32)
    delta = jnp.where(radius > 0.0, 2.0 * radius / num_levels, 1.0)
    c = (theta - theta_hat + radius) / delta
    fl = jnp.floor(c)
    p = c - fl
    q = jnp.clip(fl + (u < p).astype(jnp.float32), 0.0, num_levels)
    hat = theta_hat + delta * q - radius
    zero = radius <= 0.0
    q = jnp.where(zero, jnp.zeros_like(q), q)
    hat = jnp.where(zero, theta_hat, hat)
    return q, hat, radius


def matmul_ref(x, w):
    """Reference dense matmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def admm_rhs_ref(b, lam_l, lam_r, th_l, th_r, mask_l, mask_r, rho):
    """Reference fused rhs assembly."""
    rho = jnp.float32(rho)
    return (
        b
        + jnp.float32(mask_l) * (lam_l + rho * th_l)
        + jnp.float32(mask_r) * (-lam_r + rho * th_r)
    )
