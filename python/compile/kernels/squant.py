"""L1 Pallas kernel: the stochastic quantizer of Q-GADMM (Sec. III-A).

Elementwise over the model vector, staged through VMEM-sized tiles:

    c      = (theta - theta_hat + R) / delta          (eq. (6))
    p      = c - floor(c)                             (eq. (10))
    q      = floor(c) + [u < p]                       (eq. (7))
    th_new = theta_hat + delta * q - R                (eq. (13))

The radius ``R = max|theta - theta_hat|`` is a full-vector reduction, so it
is computed by the calling L2 graph (one pass) and fed to the kernel as a
scalar; the kernel is the bandwidth-bound elementwise hot loop.

Arithmetic is expression-identical to the Rust native quantizer
(``rust/src/quant/mod.rs``): fed the same uniforms the two backends emit
identical integer levels (the `artifact_parity` integration test pins
this).

TPU mapping (DESIGN.md §5): one grid axis over d/BLOCK tiles; five streams
(theta, theta_hat, u in; q, theta_hat out) of BLOCK f32 each ⇒ VMEM
footprint 5·BLOCK·4 B = 160 KiB at BLOCK = 8192, well under a core's
~16 MiB VMEM with generous double-buffering headroom. All ops are VPU
elementwise — no MXU, no transcendentals.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size along the model dimension. 8192 f32 = 32 KiB per stream.
BLOCK = 8192


def _squant_kernel(scalar_ref, theta_ref, hat_ref, u_ref, q_ref, out_hat_ref):
    """One VMEM tile of the quantizer. scalar_ref = (radius, delta, num_levels)."""
    radius = scalar_ref[0]
    delta = scalar_ref[1]
    num_levels = scalar_ref[2]
    theta = theta_ref[...]
    hat = hat_ref[...]
    u = u_ref[...]

    c = (theta - hat + radius) / delta
    fl = jnp.floor(c)
    p = c - fl
    up = (u < p).astype(jnp.float32)
    q = jnp.clip(fl + up, 0.0, num_levels)
    q_ref[...] = q
    out_hat_ref[...] = hat + delta * q - radius


@functools.partial(jax.jit, static_argnames=("bits",))
def squant(theta, theta_hat, u, bits: int):
    """Quantize ``theta`` against ``theta_hat`` with stochastic rounding.

    Args:
      theta: f32[d] current model.
      theta_hat: f32[d] previously-quantized model (the shared mirror).
      u: f32[d] iid uniforms in [0, 1) deciding the rounding.
      bits: quantizer resolution b (levels = 2**b - 1).

    Returns:
      (q, theta_hat_new, radius): f32[d] integer levels, f32[d] reconstructed
      model, f32[] radius. radius == 0 ⇒ q = 0 and theta_hat_new = theta_hat
      (matches the Rust backend's zero-radius short-circuit).
    """
    d = theta.shape[0]
    num_levels = jnp.float32((1 << bits) - 1)
    radius = jnp.max(jnp.abs(theta - theta_hat)).astype(jnp.float32)
    # Guard against radius == 0 (theta == theta_hat exactly): delta=1 makes
    # the kernel compute q = floor(0/1 + 0) safely; outputs are masked below.
    safe_delta = jnp.where(radius > 0.0, 2.0 * radius / num_levels, 1.0)
    scalars = jnp.stack([radius, safe_delta, num_levels])

    padded = pl.cdiv(d, BLOCK) * BLOCK
    pad = padded - d
    theta_p = jnp.pad(theta, (0, pad))
    hat_p = jnp.pad(theta_hat, (0, pad))
    u_p = jnp.pad(u, (0, pad))

    q_p, hat_new_p = pl.pallas_call(
        _squant_kernel,
        grid=(padded // BLOCK,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),  # scalars replicated per tile
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), jnp.float32),
            jax.ShapeDtypeStruct((padded,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(scalars, theta_p, hat_p, u_p)

    q = q_p[:d]
    hat_new = hat_new_p[:d]
    zero = radius <= 0.0
    q = jnp.where(zero, jnp.zeros_like(q), q)
    hat_new = jnp.where(zero, theta_hat, hat_new)
    return q, hat_new, radius
