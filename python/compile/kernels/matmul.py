"""L1 Pallas kernel: VMEM-tiled matmul for the MLP forward path.

The paper's DNN task is dominated by dense layers (784-128-64-10); this is
the MXU-shaped kernel the L2 model graphs call for every matmul, with a
``jax.custom_vjp`` so the Q-SGADMM local training step can differentiate
through it (the backward passes are themselves Pallas matmuls of the
transposed operands).

TPU mapping (DESIGN.md §5): grid (M/BM, N/BN, K/BK); x tile (BM, BK) and
w tile (BK, BN) staged to VMEM, f32 accumulation in the output tile across
the K axis (revisited output block). At the defaults (BM, BK, BN) =
(128, 128, 128) the working set is 3·128·128·4 B = 192 KiB. Operands are
zero-padded to tile multiples by the wrapper.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128
BK = 128
BN = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad2(a, bm, bn):
    m, n = a.shape
    pm = pl.cdiv(m, bm) * bm - m
    pn = pl.cdiv(n, bn) * bn - n
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
    return a


def _matmul_raw(x, w):
    """Tiled pallas matmul of f32[m,k] @ f32[k,n] (zero-padded to tiles)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    xp = _pad2(x, BM, BK)
    wp = _pad2(w, BK, BN)
    mp, kp = xp.shape
    _, np_ = wp.shape
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // BM, np_ // BN, kp // BK),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def pallas_matmul(x, w):
    """Differentiable tiled matmul: both forward and backward run on the
    L1 kernel, so the whole Q-SGADMM local step lowers into Pallas tiles."""
    return _matmul_raw(x, w)


def _fwd(x, w):
    return _matmul_raw(x, w), (x, w)


def _bwd(res, g):
    x, w = res
    # dx = g @ wᵀ ; dw = xᵀ @ g — transposes fused into the same kernel.
    dx = _matmul_raw(g, w.T)
    dw = _matmul_raw(x.T, g)
    return dx, dw


pallas_matmul.defvjp(_fwd, _bwd)


@functools.partial(jax.jit)
def matmul(x, w):
    """Jitted convenience wrapper (tests, eval graphs)."""
    return pallas_matmul(x, w)
