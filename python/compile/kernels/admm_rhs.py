"""L1 Pallas kernel: fused ADMM right-hand-side assembly for the linear
regression local solve (eqs. (14)-(17)).

    rhs = b + mask_l * (lam_l + rho * th_l) + mask_r * (-lam_r + rho * th_r)

Fusing the four masked vector terms avoids materializing intermediates in
HBM; at d = 6 it is a single VMEM tile, but the kernel is written blocked
so the same artifact family scales to large-d sweeps.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048


def _rhs_kernel(scalar_ref, b_ref, lam_l_ref, lam_r_ref, th_l_ref, th_r_ref, o_ref):
    rho = scalar_ref[0]
    mask_l = scalar_ref[1]
    mask_r = scalar_ref[2]
    o_ref[...] = (
        b_ref[...]
        + mask_l * (lam_l_ref[...] + rho * th_l_ref[...])
        + mask_r * (-lam_r_ref[...] + rho * th_r_ref[...])
    )


@jax.jit
def admm_rhs(b, lam_l, lam_r, th_l, th_r, mask_l, mask_r, rho):
    """Assemble the local-solve rhs. Masks are 0.0/1.0 f32 scalars encoding
    the presence of the left/right neighbor (chain ends have one)."""
    d = b.shape[0]
    scalars = jnp.stack(
        [jnp.float32(rho), jnp.float32(mask_l), jnp.float32(mask_r)]
    )
    padded = pl.cdiv(d, BLOCK) * BLOCK
    pad = padded - d

    def p(v):
        return jnp.pad(v, (0, pad))

    out = pl.pallas_call(
        _rhs_kernel,
        grid=(padded // BLOCK,),
        in_specs=[pl.BlockSpec((3,), lambda i: (0,))]
        + [pl.BlockSpec((BLOCK,), lambda i: (i,))] * 5,
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=True,
    )(scalars, p(b), p(lam_l), p(lam_r), p(th_l), p(th_r))
    return out[:d]
