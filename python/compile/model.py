"""L2: the paper's per-worker compute graphs in JAX, calling the L1 Pallas
kernels, AOT-lowered by ``aot.py`` into the HLO artifacts the Rust
coordinator executes through PJRT.

Graphs (one artifact each):

* ``quantize_step``  — radius + L1 ``squant`` kernel (eqs. (6)-(13));
* ``linreg_local``   — the closed-form GADMM primal update for linear
  regression (eqs. (14)-(17)): L1 ``admm_rhs`` kernel + an unrolled
  Cholesky solve (plain HLO ops only — no LAPACK custom-calls, which the
  pinned xla_extension 0.5.1 could not resolve);
* ``mlp_local``      — the Q-SGADMM local solve (Sec. V-B): 10 unrolled
  Adam steps on CE(minibatch) + the augmented-Lagrangian penalty, forward
  and backward through the L1 ``pallas_matmul`` kernel;
* ``mlp_grad``       — one minibatch CE gradient (the SGD/QSGD uplink);
* ``mlp_eval``       — batch logits for accuracy evaluation.

Parameter layout is the flat row-major ``[in, out]`` order of
``rust/src/model/mlp.rs`` (bias-free 784-128-64-10 ⇒ d = 109,184).
"""

import jax
import jax.numpy as jnp

from compile.kernels.admm_rhs import admm_rhs
from compile.kernels.matmul import pallas_matmul
from compile.kernels.squant import squant

# ---------------------------------------------------------------------------
# MLP definition (must mirror rust/src/model/mlp.rs exactly).
# ---------------------------------------------------------------------------

MLP_IN, MLP_H1, MLP_H2, MLP_OUT = 784, 128, 64, 10
MLP_DIMS = MLP_IN * MLP_H1 + MLP_H1 * MLP_H2 + MLP_H2 * MLP_OUT  # 109,184

ADAM_LR = 1e-3
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
LOCAL_ITERS = 10


def unflatten(theta):
    """Flat f32[109184] -> (w1[784,128], w2[128,64], w3[64,10])."""
    o1 = MLP_IN * MLP_H1
    o2 = o1 + MLP_H1 * MLP_H2
    w1 = theta[:o1].reshape(MLP_IN, MLP_H1)
    w2 = theta[o1:o2].reshape(MLP_H1, MLP_H2)
    w3 = theta[o2:].reshape(MLP_H2, MLP_OUT)
    return w1, w2, w3


def mlp_logits(theta, x):
    """Forward pass through the L1 tiled-matmul kernel."""
    w1, w2, w3 = unflatten(theta)
    h1 = jax.nn.relu(pallas_matmul(x, w1))
    h2 = jax.nn.relu(pallas_matmul(h1, w2))
    return pallas_matmul(h2, w3)


def mlp_ce_loss(theta, x, y_onehot):
    """Mean cross-entropy over the minibatch."""
    logits = mlp_logits(theta, x)
    logz = jax.scipy.special.logsumexp(logits, axis=1)
    picked = jnp.sum(logits * y_onehot, axis=1)
    return jnp.mean(logz - picked)


def _penalty(theta, lam_l, lam_r, th_l, th_r, mask_l, mask_r, rho):
    """Augmented-Lagrangian penalty of eq. (14)/(16), masked at chain ends."""
    left = mask_l * (
        jnp.vdot(lam_l, th_l - theta) + 0.5 * rho * jnp.sum((th_l - theta) ** 2)
    )
    right = mask_r * (
        jnp.vdot(lam_r, theta - th_r) + 0.5 * rho * jnp.sum((theta - th_r) ** 2)
    )
    return left + right


def mlp_local_adam(theta, x, y_onehot, lam_l, lam_r, th_l, th_r, mask_l, mask_r, rho):
    """The Q-SGADMM local solve: LOCAL_ITERS fresh-state Adam steps on
    CE(minibatch; θ) + penalty(θ; λ, θ̂). Returns the updated flat model."""

    def aug_loss(t):
        return mlp_ce_loss(t, x, y_onehot) + _penalty(
            t, lam_l, lam_r, th_l, th_r, mask_l, mask_r, rho
        )

    grad_fn = jax.grad(aug_loss)
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    for t in range(1, LOCAL_ITERS + 1):
        g = grad_fn(theta)
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mhat = m / (1.0 - ADAM_B1**t)
        vhat = v / (1.0 - ADAM_B2**t)
        theta = theta - ADAM_LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return theta


def mlp_grad(theta, x, y_onehot):
    """Minibatch CE gradient — the (Q)SGD uplink payload."""
    return jax.grad(mlp_ce_loss)(theta, x, y_onehot)


def mlp_eval(theta, x):
    """Batch logits for accuracy evaluation."""
    return mlp_logits(theta, x)


# ---------------------------------------------------------------------------
# Linear-regression local solve.
# ---------------------------------------------------------------------------


def chol_solve_unrolled(a, rhs, d: int):
    """Cholesky solve of an SPD d×d system, fully unrolled at trace time.

    Emits only mul/add/sqrt/div HLO ops — deliberately avoiding
    ``jnp.linalg`` (which lowers to LAPACK custom-calls the pinned
    xla_extension cannot execute). d = 6 ⇒ ~100 scalar ops.
    """
    l = [[None] * d for _ in range(d)]
    for i in range(d):
        for j in range(i + 1):
            s = a[i, j]
            for k in range(j):
                s = s - l[i][k] * l[j][k]
            if i == j:
                l[i][j] = jnp.sqrt(s)
            else:
                l[i][j] = s / l[j][j]
    # Forward substitution: L y = rhs
    y = [None] * d
    for i in range(d):
        s = rhs[i]
        for k in range(i):
            s = s - l[i][k] * y[k]
        y[i] = s / l[i][i]
    # Backward: Lᵀ x = y
    x = [None] * d
    for i in reversed(range(d)):
        s = y[i]
        for k in range(i + 1, d):
            s = s - l[k][i] * x[k]
        x[i] = s / l[i][i]
    return jnp.stack(x)


def linreg_local(a, b, lam_l, lam_r, th_l, th_r, mask_l, mask_r, rho):
    """GADMM primal update (eqs. (14)-(17)):
    ``(A + ρ·(mask_l+mask_r)·I) θ = admm_rhs(...)``."""
    d = b.shape[0]
    rhs = admm_rhs(b, lam_l, lam_r, th_l, th_r, mask_l, mask_r, rho)
    mat = a + rho * (mask_l + mask_r) * jnp.eye(d, dtype=jnp.float32)
    return chol_solve_unrolled(mat, rhs, d)


# ---------------------------------------------------------------------------
# Quantizer step (wraps the L1 kernel; one artifact per (d, bits)).
# ---------------------------------------------------------------------------


def quantize_step(theta, theta_hat, u, bits: int):
    """See kernels/squant.py; returns (q, theta_hat_new, radius)."""
    return squant(theta, theta_hat, u, bits)
