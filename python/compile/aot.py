"""AOT lowering: JAX L2 graphs -> HLO text artifacts + manifest.json.

HLO *text* is the interchange format (NOT ``.serialize()``): jax >= 0.5
emits protos with 64-bit instruction ids that the pinned xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Each artifact is one jitted function lowered at fixed shapes; the manifest
records input/output shapes, dtypes and the constants baked into the
lowering (batch size, bits, Adam hyperparameters) so the Rust runtime can
validate call sites at load time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _shapes(specs):
    return [list(s.shape) for s in specs]


def build_artifacts(mlp_batch: int, eval_batch: int, linreg_d: int, quant_dims, bits_map):
    """Returns {name: (lowered, input_specs, output_info, constants)}."""
    arts = {}

    # --- quantizer artifacts: one per (d, bits) pair -----------------------
    for d in quant_dims:
        bits = bits_map[d]
        fn = jax.jit(lambda t, h, u, _b=bits: model.quantize_step(t, h, u, _b))
        ins = [spec(d), spec(d), spec(d)]
        arts[f"squant_d{d}_b{bits}"] = (
            fn.lower(*ins),
            ins,
            {"outputs": [[d], [d], []]},
            {"bits": bits, "dims": d},
        )

    # --- linreg local solve ------------------------------------------------
    d = linreg_d
    fn = jax.jit(model.linreg_local)
    ins = [spec(d, d), spec(d), spec(d), spec(d), spec(d), spec(d), spec(), spec(), spec()]
    arts[f"linreg_local_d{d}"] = (
        fn.lower(*ins),
        ins,
        {"outputs": [[d]]},
        {"dims": d},
    )

    # --- MLP artifacts ------------------------------------------------------
    dd = model.MLP_DIMS
    b = mlp_batch
    fn = jax.jit(model.mlp_local_adam)
    ins = [
        spec(dd),
        spec(b, model.MLP_IN),
        spec(b, model.MLP_OUT),
        spec(dd),
        spec(dd),
        spec(dd),
        spec(dd),
        spec(),
        spec(),
        spec(),
    ]
    arts["mlp_local"] = (
        fn.lower(*ins),
        ins,
        {"outputs": [[dd]]},
        {
            "dims": dd,
            "batch": b,
            "local_iters": model.LOCAL_ITERS,
            "adam_lr": model.ADAM_LR,
        },
    )

    fn = jax.jit(model.mlp_grad)
    ins = [spec(dd), spec(b, model.MLP_IN), spec(b, model.MLP_OUT)]
    arts["mlp_grad"] = (
        fn.lower(*ins),
        ins,
        {"outputs": [[dd]]},
        {"dims": dd, "batch": b},
    )

    fn = jax.jit(model.mlp_eval)
    ins = [spec(dd), spec(eval_batch, model.MLP_IN)]
    arts["mlp_eval"] = (
        fn.lower(*ins),
        ins,
        {"outputs": [[eval_batch, model.MLP_OUT]]},
        {"dims": dd, "batch": eval_batch},
    )

    return arts


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--mlp-batch", type=int, default=100)
    ap.add_argument("--eval-batch", type=int, default=256)
    ap.add_argument("--linreg-d", type=int, default=6)
    ap.add_argument(
        "--skip-mlp",
        action="store_true",
        help="only build the (fast) linreg + quantizer artifacts",
    )
    args = ap.parse_args()

    quant_dims = [args.linreg_d, model.MLP_DIMS]
    bits_map = {args.linreg_d: 2, model.MLP_DIMS: 8}
    arts = build_artifacts(
        args.mlp_batch, args.eval_batch, args.linreg_d, quant_dims, bits_map
    )
    if args.skip_mlp:
        arts = {k: v for k, v in arts.items() if not k.startswith("mlp")}

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text-v1", "artifacts": {}}
    for name, (lowered, ins, outs, consts) in arts.items():
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": _shapes(ins),
            "outputs": outs["outputs"],
            "constants": consts,
        }
        print(f"wrote {fname}: {len(text)} chars, inputs={_shapes(ins)}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
